"""Bench-trajectory regression gate.

Diffs fresh ``benchmarks/results/BENCH_*.json`` artifacts against the
committed baselines in ``benchmarks/baselines/`` and fails (exit 1) on
regressions, so a PR that silently halves serving throughput or doubles
modeled joules/token trips CI instead of landing.

Two threshold classes, because CI machines are noisy but models are not:

* **tight (25 %)** — deterministic metrics: modeled joules/token (pure
  function of the compiled HLO + call counts), speculative acceptance
  rate and target-steps/token (greedy, fixed seeds), paged-KV live/ring
  byte ratio (pure allocator accounting).  A >25 % move here is a real
  behavior change, never noise.
* **loose (3x)** — wall-clock metrics (tok/s, p99 TTFT/ITL): shared CI
  runners routinely swing 2x; only a catastrophic slowdown should gate.

Each metric carries a direction: ``lower`` means a larger value is the
regression (latency, joules/token), ``higher`` means a smaller value is
(throughput, acceptance).  Improvements never fail, and are shown in the
trajectory table so drive-by wins get recorded by ``--update``.

Usage::

    PYTHONPATH=src python scripts/bench_compare.py serving speculative paged_kv
    PYTHONPATH=src python scripts/bench_compare.py --update serving ...

``--update`` rewrites the committed baselines from the current results
(run after an intentional perf change, commit the diff).  A missing
baseline or result file warns and passes — first runs must not gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(REPO, "benchmarks", "results")
BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")

TIGHT = 0.25    # deterministic metrics: >25 % move == real change
LOOSE = 3.0     # wall-clock metrics: 2x CI noise is routine, 3x gates


def _get(d: Dict[str, Any], path: str) -> Optional[float]:
    cur: Any = d
    for k in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(k)] if int(k) < len(cur) else None
        elif isinstance(cur, dict):
            cur = cur.get(k)
        if cur is None:
            return None
    return float(cur) if isinstance(cur, (int, float)) else None


# metric spec: result-json path -> (direction, rel threshold)
# direction "lower": regression when value grows past (1+thr)*baseline
# direction "higher": regression when value drops below baseline/(1+thr)

def _serving_metrics(d: Dict[str, Any]) -> Dict[str, tuple]:
    out = {}
    for i, m in enumerate(d.get("loads", [])):
        lf = m.get("load_factor", i)
        out[f"loads.{i}.tok_per_s"] = ("higher", LOOSE,
                                       f"load {lf}x tok/s")
        out[f"loads.{i}.ttft_ms.p99"] = ("lower", LOOSE,
                                         f"load {lf}x TTFT p99 ms")
        out[f"loads.{i}.itl_ms.p99"] = ("lower", LOOSE,
                                        f"load {lf}x ITL p99 ms")
    out["energy_breakdown.joules_per_token"] = (
        "lower", TIGHT, "joules/token (modeled)")
    return out


def _speculative_metrics(d: Dict[str, Any]) -> Dict[str, tuple]:
    out = {}
    for name in d.get("cells", {}):
        out[f"cells.{name}.acceptance_rate"] = (
            "higher", TIGHT, f"{name} acceptance")
        out[f"cells.{name}.target_steps_per_token"] = (
            "lower", TIGHT, f"{name} target steps/token")
        out[f"cells.{name}.energy.joules_per_token"] = (
            "lower", TIGHT, f"{name} joules/token (modeled)")
        out[f"cells.{name}.tok_per_s.speculative"] = (
            "higher", LOOSE, f"{name} tok/s")
    return out


def _paged_kv_metrics(d: Dict[str, Any]) -> Dict[str, tuple]:
    out = {}
    for fmt in d.get("live_vs_ring", {}):
        out[f"live_vs_ring.{fmt}"] = (
            "lower", TIGHT, f"{fmt} live/ring bytes")
    return out


EXTRACTORS = {"serving": _serving_metrics,
              "speculative": _speculative_metrics,
              "paged_kv": _paged_kv_metrics}


def _load(path: str) -> Optional[Dict[str, Any]]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def compare_bench(name: str, results_dir: str, baseline_dir: str,
                  update: bool) -> tuple:
    """Returns (rows, n_regressions) for one bench."""
    res = _load(os.path.join(results_dir, f"BENCH_{name}.json"))
    if res is None:
        print(f"[bench_compare] WARN: no results for {name} "
              f"(run the bench first) — skipping")
        return [], 0
    metrics = EXTRACTORS[name](res)
    flat = {p: _get(res, p) for p in metrics}
    base_path = os.path.join(baseline_dir, f"BENCH_{name}.json")
    if update:
        os.makedirs(baseline_dir, exist_ok=True)
        with open(base_path, "w") as f:
            json.dump({p: v for p, v in flat.items() if v is not None},
                      f, indent=1, sort_keys=True)
        print(f"[bench_compare] baseline updated -> {base_path}")
        return [], 0
    base = _load(base_path)
    if base is None:
        print(f"[bench_compare] WARN: no committed baseline for {name} "
              f"({base_path}) — passing")
        return [], 0
    rows, bad = [], 0
    for path, (direction, thr, label) in metrics.items():
        cur, ref = flat.get(path), base.get(path)
        if cur is None or ref is None or ref == 0:
            continue
        ratio = cur / ref
        if direction == "lower":
            regressed = ratio > 1.0 + thr
        else:
            regressed = ratio < 1.0 / (1.0 + thr)
        bad += regressed
        rows.append((name, label, ref, cur, ratio, direction, thr,
                     regressed))
    return rows, bad


def print_table(rows) -> None:
    if not rows:
        return
    print(f"{'bench':<12s} {'metric':<32s} {'baseline':>12s} "
          f"{'current':>12s} {'ratio':>7s}  verdict")
    for name, label, ref, cur, ratio, direction, thr, reg in rows:
        arrow = "<=" if direction == "lower" else ">="
        verdict = ("REGRESSED" if reg else
                   "improved" if (ratio < 1) == (direction == "lower")
                   and abs(ratio - 1) > 0.02 else "ok")
        print(f"{name:<12s} {label:<32s} {ref:>12.4g} {cur:>12.4g} "
              f"{ratio:>7.2f}  {verdict} "
              f"(gate: ratio {arrow} {1 + thr:.2f}x)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff BENCH_*.json against committed baselines")
    ap.add_argument("benches", nargs="+", choices=sorted(EXTRACTORS))
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from current results")
    args = ap.parse_args(argv)
    all_rows, total_bad = [], 0
    for name in args.benches:
        rows, bad = compare_bench(name, args.results_dir,
                                  args.baseline_dir, args.update)
        all_rows.extend(rows)
        total_bad += bad
    print_table(all_rows)
    if total_bad:
        print(f"[bench_compare] FAIL: {total_bad} metric(s) regressed "
              f"past their gate")
        return 1
    if all_rows:
        print(f"[bench_compare] OK: {len(all_rows)} metrics within gates")
    return 0


if __name__ == "__main__":
    sys.exit(main())
