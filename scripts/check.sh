#!/usr/bin/env bash
# Tier-1 verify for this repo.
#
#   scripts/check.sh            # full suite (includes ~5 min system tests)
#   scripts/check.sh --smoke    # fast subset: skips tests/test_system.py
#
# Extra pytest args pass through, e.g. scripts/check.sh --smoke -k kv_cache
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=()
SMOKE=0
if [[ "${1:-}" == "--smoke" ]]; then
  shift
  SMOKE=1
  ARGS+=(--ignore=tests/test_system.py)
fi

# per-test wall-clock cap when pytest-timeout is available (the chaos
# suite asserts no-hang invariants — a regression should fail, not stall)
if python -c "import pytest_timeout" 2>/dev/null; then
  ARGS+=(--timeout=600 --timeout-method=thread)
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "${ARGS[@]}" "$@"

if [[ "$SMOKE" == 1 ]]; then
  # legacy stats dicts are views over the metrics registry; pin the
  # equivalence so the two surfaces can't drift apart
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/stats_consistency.py
fi
