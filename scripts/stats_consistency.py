"""Assert legacy ``stats`` keys stay consistent with the metrics registry.

The engines' ``stats`` dicts are now :class:`repro.obs.StatsView` facades
over one shared :class:`repro.obs.MetricsRegistry`; this script serves a
couple of smoke requests through the threaded orchestrator and checks
every legacy key — engine and orchestrator — against the registry
snapshot value it is supposed to be a view of.  Run by
``scripts/check.sh --smoke`` so a drift between the two surfaces fails
CI, not a dashboard.

  PYTHONPATH=src python scripts/stats_consistency.py
"""
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.obs import Tracer
from repro.serve.engine import ServeConfig, ServingEngine
from repro.serve.orchestrator import (Orchestrator, OrchestratorConfig,
                                      StreamingRequest)


def main() -> int:
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=2, max_len=64, kv_format="posit8")
    eng = ServingEngine(cfg, params, scfg, tracer=Tracer(enabled=True))
    rng = np.random.default_rng(0)
    sreqs = [StreamingRequest(rng.integers(0, cfg.vocab, 6).tolist(),
                              max_new=4) for _ in range(3)]
    with Orchestrator(eng, OrchestratorConfig(detokenize=False)) as orch:
        for s in sreqs:
            assert orch.submit(s, timeout=60.0)
        for s in sreqs:
            assert s.wait(120.0), "stream did not finish"
        snap = eng.metrics.snapshot()
        flat = {**snap["counters"], **snap["gauges"]}
        bad = []
        for label, view in (("engine", eng.stats), ("orch", orch.stats)):
            for key in view:
                name = view.metric_name(key)
                if name not in flat:
                    bad.append(f"{label}.stats[{key!r}] -> {name} "
                               f"missing from registry snapshot")
                elif flat[name] != view[key]:
                    bad.append(f"{label}.stats[{key!r}] = {view[key]} but "
                               f"registry {name} = {flat[name]}")
    if bad:
        print("stats/registry drift:", *bad, sep="\n  ")
        return 1
    n_tok = sum(len(s.out_tokens) for s in sreqs)
    assert n_tok > 0 and flat["engine.tokens"] >= n_tok
    assert flat["orch.submitted"] == len(sreqs)
    assert flat["orch.finished"] == len(sreqs)
    print(f"stats consistency OK: {len(dict(eng.stats))} engine + "
          f"{len(dict(orch.stats))} orchestrator keys match the registry")
    return 0


if __name__ == "__main__":
    sys.exit(main())
