"""Roofline reporting (deliverable g): read the dry-run result JSONs and
emit the per-(arch x shape) three-term table, bottleneck attribution, and
hillclimb-candidate selection.

  PYTHONPATH=src python -m benchmarks.roofline [--dir benchmarks/results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load(dir_: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        try:
            out.append(json.load(open(path)))
        except json.JSONDecodeError:
            continue
    return out


def fmt_row(r: Dict) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — "
                f"| — | — | — | — |")
    ro = r["roofline"]
    mem = r.get("memory_analysis", {})
    hbm_gb = (mem.get("temp_size_in_bytes", 0)
              + mem.get("argument_size_in_bytes", 0)) / 1e9
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ro['t_compute_s']:.4f} | {ro['t_memory_s']:.4f} "
            f"| {ro['t_collective_s']:.4f} | {ro['dominant']} "
            f"| {ro['useful_flops_ratio']:.3f} "
            f"| {ro['roofline_fraction']:.4f} | {hbm_gb:.1f} |")


HEADER = ("| arch | shape | mesh | t_compute (s) | t_memory (s) "
          "| t_collective (s) | bottleneck | 6ND/HLO | roofline-frac "
          "| HBM GB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def pick_hillclimb(rows: List[Dict]) -> Dict[str, Dict]:
    """worst roofline fraction / most collective-bound / most TC-representative."""
    live = [r for r in rows if not r.get("skipped")
            and not r.get("tag")
            and r.get("mesh") == "16x16"
            and not r.get("variant", {}).get("policy", "bf16") != "bf16"]
    train = [r for r in live if r["kind"] == "train"]
    by_frac = sorted(train, key=lambda r: r["roofline"]["roofline_fraction"])
    by_coll = sorted(live, key=lambda r: -(
        r["roofline"]["t_collective_s"]
        / max(max(r["roofline"]["t_compute_s"],
                  r["roofline"]["t_memory_s"]), 1e-12)))
    # representative of the paper's technique: decode-on-read posit packing
    # targets weight+KV HBM reads — the dense decode cell with the largest
    # memory term (MoE decode reads only active experts; dense reads all)
    decode = [r for r in live if r["kind"] == "decode"
              and r["shape"] != "long_500k"]
    dense = [r for r in decode if "moe" not in r["arch"]]
    by_repr = sorted(dense or decode,
                     key=lambda r: -r["roofline"]["t_memory_s"])
    return {
        "worst_fraction": by_frac[0] if by_frac else None,
        "most_collective_bound": by_coll[0] if by_coll else None,
        "most_representative": by_repr[0] if by_repr else None,
    }


def main(verbose=True, dir_="benchmarks/results/dryrun"):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=dir_)
    args, _ = ap.parse_known_args()
    rows = load(args.dir)
    base = [r for r in rows if not r.get("tag")
            and ("variant" not in r
                 or r["variant"].get("policy", "bf16") == "bf16")]
    if verbose:
        print(HEADER)
        for r in base:
            print(fmt_row(r))
        picks = pick_hillclimb(base)
        print("\nhillclimb candidates:")
        for why, r in picks.items():
            if r:
                print(f"  {why}: {r['arch']} x {r['shape']} "
                      f"(dominant={r['roofline']['dominant']}, "
                      f"frac={r['roofline']['roofline_fraction']:.4f})")
    return {"n_cells": len(base),
            "n_ok": sum(1 for r in base if not r.get("skipped")
                        and "error" not in r)}


if __name__ == "__main__":
    main()
