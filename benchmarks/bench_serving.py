"""Serving latency percentiles under offered load (TTFT / ITL sweep).

Drives the async :class:`~repro.serve.orchestrator.Orchestrator` (the
three-stage prefill→insert→generate engine underneath) with Poisson
request arrivals at several offered loads, expressed as multiples of the
engine's measured single-stream service rate.  Per load point it reports
host-side latency percentiles — the numbers a serving deployment is
actually graded on:

  * TTFT  — submit-to-first-token, p50/p99 (prefill + queueing);
  * ITL   — inter-token latency within a stream, p50/p99 (decode round
    cadence; batched speculative commits would share one stamp);
  * achieved vs offered throughput (requests/s and tokens/s).

At offered load <= the service rate the queue stays short and p99 TTFT
tracks prefill latency; past saturation (the 2x point) queueing delay
dominates and p99 TTFT grows with the backlog — the sweep makes that
knee visible.  CPU-reference numbers on this container; the shape of the
curve, not the absolute latencies, is the artifact.

Rate accounting: the measurement window runs from the FIRST submit to
the LAST finish (both ``perf_counter`` stamps recorded by the
orchestrator), so achieved_rps can never exceed the offered rate beyond
the N/(N-1) edge correction — asserted per load point.  Each load point
also carries a per-stage wall-clock breakdown (dispatch vs device-sync
per engine stage, orchestrator overhead) from the span tracer
(:mod:`repro.obs`); set ``REPRO_TRACE=1`` to additionally write the full
Chrome trace to ``results/BENCH_serving.trace.json``.

Each load point also reports modeled **energy** (:mod:`repro.obs.energy`:
TALU pJ/MAC x HLO FLOPs + DRAM pJ/byte x HBM bytes, times the per-stage
call-counter deltas over the window) as joules/token and tok/J, plus SLO
violation counts against fixed TTFT/ITL thresholds; the cumulative
``energy_breakdown`` (per-stage precision mix included) lands in the
JSON, and every request's lifecycle decomposition is appended to
``results/BENCH_serving.requests.jsonl``.

Writes ``benchmarks/results/BENCH_serving.json``.

  PYTHONPATH=src python -m benchmarks.run serving
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.obs import EnergyAccountant, Tracer, stage_breakdown
from repro.serve.engine import ServeConfig, ServingEngine
from repro.serve.orchestrator import (Orchestrator, OrchestratorConfig,
                                      StreamingRequest)

LOAD_FACTORS = (0.5, 1.0, 2.0)      # x the measured service rate
MAX_BATCH, MAX_LEN, MAX_NEW, N_REQ = 2, 64, 8, 8
KV_FORMAT = "posit8"
# fixed SLOs for the violation counters: loose enough that the 0.5x load
# point passes on CI CPUs, tight enough that saturation shows up
TTFT_SLO_S, ITL_SLO_S = 2.0, 1.0
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(rng.integers(4, 13))).tolist()
            for _ in range(N_REQ)]


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else None


def _slo_counters(eng):
    c = eng.metrics.snapshot()["counters"]
    return {k: int(c.get(f"orch.slo.{k}", 0))
            for k in ("ttft_total", "ttft_violations",
                      "itl_total", "itl_violations")}


# robustness accounting: injected faults, retried stage dispatches and
# guard precision-fallback re-decodes over the load window.  All zero on
# the default fault-free run — the point is that the counters (and their
# hooks) are present on the hot serving path at no measurable cost.
_FAULT_KEYS = ("faults.injected", "stage.retries", "stage.retry_exhausted",
               "guard.nonfinite_rows", "guard.quarantined",
               "guard.fallbacks", "orch.deadline_expired",
               "orch.cancelled", "orch.watchdog_fired")


def _fault_counters(eng):
    c = eng.metrics.snapshot()["counters"]
    return {k: int(c.get(k, 0)) for k in _FAULT_KEYS}


def _run_load(eng, prompts, rate_rps, rng, acct=None, request_log=None):
    """Submit N_REQ prompts with Poisson gaps at rate_rps; return metrics."""
    ev0 = eng.stats.get("evictions", 0)
    since = eng.tracer.self_times()
    slo0 = _slo_counters(eng)
    flt0 = _fault_counters(eng)
    calls0 = acct.calls_snapshot() if acct is not None else {}
    orch = Orchestrator(eng, OrchestratorConfig(max_queue=4 * N_REQ,
                                                detokenize=False,
                                                ttft_slo_s=TTFT_SLO_S,
                                                itl_slo_s=ITL_SLO_S,
                                                request_log=request_log))
    sreqs = [StreamingRequest(p, max_new=MAX_NEW) for p in prompts]
    gaps = rng.exponential(1.0 / rate_rps, size=len(sreqs))
    for sreq, gap in zip(sreqs, gaps):
        assert orch.submit(sreq, timeout=120.0)
        time.sleep(float(gap))
    for sreq in sreqs:
        assert sreq.wait(300.0), "stream did not finish"
    orch.close()
    # measurement window: first submit -> last finish (perf_counter stamps
    # recorded by the orchestrator).  The old form started the clock
    # before the first submit and stopped it after close(), which let
    # achieved_rps exceed the offered rate at low load (the window was
    # dominated by the submit gaps, not service time).
    first_submit = min(s.submit_t for s in sreqs)
    last_submit = max(s.submit_t for s in sreqs)
    wall = max(s.finish_t for s in sreqs) - first_submit
    achieved_rps = len(sreqs) / wall
    # sanity: over this window achieved <= offered up to the edge
    # correction — N requests span only N-1 submit gaps
    measured_offered = None
    if last_submit > first_submit:
        measured_offered = (len(sreqs) - 1) / (last_submit - first_submit)
        bound = measured_offered * len(sreqs) / (len(sreqs) - 1)
        assert achieved_rps <= bound * 1.001, \
            f"achieved {achieved_rps:.3f} rps exceeds offered bound " \
            f"{bound:.3f} rps — measurement window is wrong"
    ttft = [s.ttft_s for s in sreqs]
    itl = [g for s in sreqs for g in s.itl_s()]
    tokens = sum(len(s.out_tokens) for s in sreqs)
    bd = stage_breakdown(eng.tracer, wall, since=since)
    assert bd["attributed_frac"] >= 0.9, \
        f"stage breakdown covers only {bd['attributed_frac']:.0%} of wall"
    # the tracer's queue bucket must reproduce the per-request stamps:
    # both derive from the same submit/admit perf_counter pairs
    stamp_wait = sum(s.lifecycle_deltas().get("queue_wait_s", 0.0)
                     for s in sreqs)
    trace_wait = bd["queue"].get("queue.wait", {}).get("total_s", 0.0)
    assert abs(trace_wait - stamp_wait) <= 1e-6 + 1e-3 * stamp_wait, \
        f"queue bucket {trace_wait:.6f}s != stamp sum {stamp_wait:.6f}s"
    energy = None
    if acct is not None:
        delta = acct.calls_delta(acct.calls_snapshot(), calls0)
        e = acct.breakdown(calls=delta, tokens=tokens)
        energy = {"joules": e["joules_total"],
                  "joules_per_token": e["joules_per_token"],
                  "tok_per_joule": e["tok_per_joule"]}
    slo1 = _slo_counters(eng)
    return {"offered_rps": rate_rps,
            "measured_offered_rps": measured_offered,
            "achieved_rps": achieved_rps,
            "tok_per_s": tokens / wall,
            "ttft_ms": {"p50": _pct(ttft, 50) * 1e3,
                        "p99": _pct(ttft, 99) * 1e3},
            "itl_ms": {"p50": _pct(itl, 50) * 1e3,
                       "p99": _pct(itl, 99) * 1e3},
            "evictions": eng.stats.get("evictions", 0) - ev0,
            "energy": energy,
            "slo": {k: slo1[k] - slo0[k] for k in slo1},
            "faults": {k: v - flt0[k]
                       for k, v in _fault_counters(eng).items()},
            "stage_breakdown": bd}


def run():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                       kv_format=KV_FORMAT)
    # big ring so the whole sweep survives for the optional trace export
    eng = ServingEngine(cfg, params, scfg,
                        tracer=Tracer(capacity=1 << 18, enabled=True))
    prompts = _prompts(cfg)
    acct = EnergyAccountant(eng)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    reqlog = os.path.join(RESULTS_DIR, "BENCH_serving.requests.jsonl")
    open(reqlog, "w").close()   # truncate: one file per bench run

    # calibrate: back-to-back batch (compiles all prefill buckets + the
    # decode step, so the sweep below measures steady-state latency)
    rng = np.random.default_rng(1)
    warm = _run_load(eng, prompts, rate_rps=1e3, rng=rng)
    service_rps = warm["achieved_rps"]

    out = {"shape": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                     "max_new": MAX_NEW, "requests": N_REQ,
                     "kv_format": KV_FORMAT},
           "slo": {"ttft_s": TTFT_SLO_S, "itl_s": ITL_SLO_S},
           "service_rps": service_rps, "loads": [],
           "request_log": os.path.basename(reqlog)}
    for f in LOAD_FACTORS:
        m = _run_load(eng, prompts, rate_rps=f * service_rps, rng=rng,
                      acct=acct, request_log=reqlog)
        m["load_factor"] = f
        out["loads"].append(m)
    # cumulative table (per-stage pJ, precision mix) over the whole run
    out["energy_breakdown"] = acct.breakdown()
    if os.environ.get("REPRO_TRACE"):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, "BENCH_serving.trace.json")
        eng.tracer.write_chrome_trace(path)
        out["trace_file"] = os.path.basename(path)
    return out


def main(verbose=False):
    out = run()
    if verbose:
        print(f"[serving] service rate {out['service_rps']:.2f} req/s "
              f"({out['shape']['requests']} reqs, "
              f"max_new={out['shape']['max_new']})")
        for m in out["loads"]:
            bd = m["stage_breakdown"]
            en = m["energy"] or {}
            tpj = en.get("tok_per_joule")
            ej = f", {tpj:.0f} tok/J" if tpj else ""
            slo = m["slo"]
            print(f"  load {m['load_factor']:.1f}x: offered "
                  f"{m['offered_rps']:.2f} rps, achieved "
                  f"{m['achieved_rps']:.2f} rps | TTFT p50/p99 "
                  f"{m['ttft_ms']['p50']:.0f}/{m['ttft_ms']['p99']:.0f} ms"
                  f" | ITL p50/p99 {m['itl_ms']['p50']:.0f}/"
                  f"{m['itl_ms']['p99']:.0f} ms | "
                  f"{m['tok_per_s']:.1f} tok/s{ej} | "
                  f"SLO viol ttft {slo['ttft_violations']}/"
                  f"{slo['ttft_total']} itl {slo['itl_violations']}/"
                  f"{slo['itl_total']} | "
                  f"{bd['attributed_frac']:.0%} wall attributed")
        eb = out["energy_breakdown"]
        if eb["joules_per_token"] is not None:
            print(f"  energy (cumulative): "
                  f"{eb['joules_per_token'] * 1e6:.1f} uJ/token, "
                  f"{eb['tok_per_joule']:.0f} tok/J")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "BENCH_serving.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main(verbose=True)
