"""Serving latency percentiles under offered load (TTFT / ITL sweep).

Drives the async :class:`~repro.serve.orchestrator.Orchestrator` (the
three-stage prefill→insert→generate engine underneath) with Poisson
request arrivals at several offered loads, expressed as multiples of the
engine's measured single-stream service rate.  Per load point it reports
host-side latency percentiles — the numbers a serving deployment is
actually graded on:

  * TTFT  — submit-to-first-token, p50/p99 (prefill + queueing);
  * ITL   — inter-token latency within a stream, p50/p99 (decode round
    cadence; batched speculative commits would share one stamp);
  * achieved vs offered throughput (requests/s and tokens/s).

At offered load <= the service rate the queue stays short and p99 TTFT
tracks prefill latency; past saturation (the 2x point) queueing delay
dominates and p99 TTFT grows with the backlog — the sweep makes that
knee visible.  CPU-reference numbers on this container; the shape of the
curve, not the absolute latencies, is the artifact.

Writes ``benchmarks/results/BENCH_serving.json`` (plus run.py's generic
``serving.json``).

  PYTHONPATH=src python -m benchmarks.run serving
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServingEngine
from repro.serve.orchestrator import (Orchestrator, OrchestratorConfig,
                                      StreamingRequest)

LOAD_FACTORS = (0.5, 1.0, 2.0)      # x the measured service rate
MAX_BATCH, MAX_LEN, MAX_NEW, N_REQ = 2, 64, 8, 8
KV_FORMAT = "posit8"
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(rng.integers(4, 13))).tolist()
            for _ in range(N_REQ)]


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else None


def _run_load(eng, prompts, rate_rps, rng):
    """Submit N_REQ prompts with Poisson gaps at rate_rps; return metrics."""
    ev0 = eng.stats.get("evictions", 0)
    orch = Orchestrator(eng, OrchestratorConfig(max_queue=4 * N_REQ,
                                                detokenize=False))
    sreqs = [StreamingRequest(p, max_new=MAX_NEW) for p in prompts]
    gaps = rng.exponential(1.0 / rate_rps, size=len(sreqs))
    t0 = time.time()
    for sreq, gap in zip(sreqs, gaps):
        assert orch.submit(sreq, timeout=120.0)
        time.sleep(float(gap))
    for sreq in sreqs:
        assert sreq.wait(300.0), "stream did not finish"
    orch.close()
    wall = time.time() - t0
    ttft = [s.ttft_s for s in sreqs]
    itl = [g for s in sreqs for g in s.itl_s()]
    tokens = sum(len(s.out_tokens) for s in sreqs)
    return {"offered_rps": rate_rps,
            "achieved_rps": len(sreqs) / wall,
            "tok_per_s": tokens / wall,
            "ttft_ms": {"p50": _pct(ttft, 50) * 1e3,
                        "p99": _pct(ttft, 99) * 1e3},
            "itl_ms": {"p50": _pct(itl, 50) * 1e3,
                       "p99": _pct(itl, 99) * 1e3},
            "evictions": eng.stats.get("evictions", 0) - ev0}


def run():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                       kv_format=KV_FORMAT)
    eng = ServingEngine(cfg, params, scfg)
    prompts = _prompts(cfg)

    # calibrate: back-to-back batch (compiles all prefill buckets + the
    # decode step, so the sweep below measures steady-state latency)
    rng = np.random.default_rng(1)
    warm = _run_load(eng, prompts, rate_rps=1e3, rng=rng)
    service_rps = warm["achieved_rps"]

    out = {"shape": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                     "max_new": MAX_NEW, "requests": N_REQ,
                     "kv_format": KV_FORMAT},
           "service_rps": service_rps, "loads": []}
    for f in LOAD_FACTORS:
        m = _run_load(eng, prompts, rate_rps=f * service_rps, rng=rng)
        m["load_factor"] = f
        out["loads"].append(m)
    return out


def main(verbose=False):
    out = run()
    if verbose:
        print(f"[serving] service rate {out['service_rps']:.2f} req/s "
              f"({out['shape']['requests']} reqs, "
              f"max_new={out['shape']['max_new']})")
        for m in out["loads"]:
            print(f"  load {m['load_factor']:.1f}x: offered "
                  f"{m['offered_rps']:.2f} rps, achieved "
                  f"{m['achieved_rps']:.2f} rps | TTFT p50/p99 "
                  f"{m['ttft_ms']['p50']:.0f}/{m['ttft_ms']['p99']:.0f} ms"
                  f" | ITL p50/p99 {m['itl_ms']['p50']:.0f}/"
                  f"{m['itl_ms']['p99']:.0f} ms")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "BENCH_serving.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main(verbose=True)
