"""Paged vs ring KV cache: live-page HBM bytes and serving throughput
per ``kv_format``.

The ring layout reserves ``max_batch x max_len`` K/V rows up front; the
paged layout allocates fixed-size posit-code pages on demand and frees
them the moment a sequence finishes.  This benchmark serves the same
mixed-length request set through both layouts and reports

  * ring reserved bytes (the dense worst case),
  * paged peak live-page bytes (the high-water mark the pool actually
    needed), and their ratio — the paging win, which stacks with the
    per-format posit packing ratios from ``bench_kv_cache``;
  * tokens/s for both layouts (CPU reference numbers on this container;
    the Pallas page-walk kernels target TPU).

Acceptance target: live-page bytes <= 0.5x the dense ring at <= 50%
average slot occupancy (short prompts against a generous max_len — the
overprovisioning scenario paging exists for).

  PYTHONPATH=src python -m benchmarks.run paged_kv
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Request, ServeConfig, ServingEngine

FORMATS = ("bf16", "posit16", "posit8", "posit4")
MAX_BATCH, MAX_LEN, PAGE_SIZE, MAX_NEW = 4, 128, 8, 8


def _requests(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(4, 17))),
                    max_new=MAX_NEW)
            for i in range(n)]


def run():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out = {"shape": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                     "page_size": PAGE_SIZE, "max_new": MAX_NEW},
           "ring_reserved_bytes": {}, "paged_reserved_bytes": {},
           "paged_peak_live_bytes": {}, "live_vs_ring": {},
           "tok_per_s": {}, "peak_occupancy": {}}
    for f in FORMATS:
        stats = {}
        for layout in ("ring", "paged"):
            eng = ServingEngine(cfg, params,
                                ServeConfig(max_batch=MAX_BATCH,
                                            max_len=MAX_LEN, kv_format=f,
                                            kv_layout=layout,
                                            page_size=PAGE_SIZE))
            reqs = _requests(cfg)
            # warm the jit caches so tok/s measures steady-state decode,
            # then reset the cumulative counters so the timed serve's
            # stats (tokens, peak live pages) exclude the warmup request
            eng.serve([Request(uid=99, prompt=reqs[0].prompt.copy(),
                               max_new=2)])
            eng.stats.update(prefills=0, decode_steps=0, tokens=0,
                             rejected=0, peak_live_pages=0)
            t0 = time.time()
            s = eng.serve(reqs)
            s["wall_s"] = time.time() - t0
            s["tok_per_s"] = s["tokens"] / max(s["wall_s"], 1e-9)
            stats[layout] = (eng, s)
        ring_eng, ring_s = stats["ring"]
        paged_eng, paged_s = stats["paged"]
        ring_bytes = ring_eng.kv_cache_bytes()
        peak_live = paged_eng.kv_cache_peak_live_bytes()
        out["ring_reserved_bytes"][f] = ring_bytes
        out["paged_reserved_bytes"][f] = paged_eng.kv_cache_bytes()
        out["paged_peak_live_bytes"][f] = peak_live
        out["live_vs_ring"][f] = round(peak_live / ring_bytes, 4)
        out["tok_per_s"][f] = {"ring": round(ring_s["tok_per_s"], 1),
                               "paged": round(paged_s["tok_per_s"], 1)}
        # peak live tokens as a fraction of the dense reservation (the
        # run's average occupancy is below this high-water mark)
        ps = PAGE_SIZE
        out["peak_occupancy"][f] = round(
            paged_s["peak_live_pages"] * ps / (MAX_BATCH * MAX_LEN), 4)
    return out


def main(verbose=True):
    out = run()
    if verbose:
        sh = out["shape"]
        print(f"== Paged vs ring KV cache (batch={sh['max_batch']}, "
              f"max_len={sh['max_len']}, page={sh['page_size']}; "
              f"CPU reference) ==")
        print(f"{'format':>8s} {'ring B':>10s} {'paged live B':>12s} "
              f"{'live/ring':>10s} {'occup':>6s} {'tok/s ring':>11s} "
              f"{'tok/s paged':>12s}")
        for f in FORMATS:
            t = out["tok_per_s"][f]
            print(f"{f:>8s} {out['ring_reserved_bytes'][f]:>10d} "
                  f"{out['paged_peak_live_bytes'][f]:>12d} "
                  f"{out['live_vs_ring'][f]:>10.3f} "
                  f"{out['peak_occupancy'][f]:>6.2f} "
                  f"{t['ring']:>11.1f} {t['paged']:>12.1f}")
    return out


if __name__ == "__main__":
    main()
