"""Table V reproduction: TALU vs the unified Posit/FP MAC (UMAC [1]).

The headline claims of the abstract: 54.6x power, 19.8x area (the text
also says "20x smaller"), 3.47x PDP, 2.76x power density.
"""
from __future__ import annotations

from . import hwmodel as hw

PAPER = {"area_x": 19.8, "power_x": 54.6, "pdp_x": 3.47,
         "pow_density_x": 2.76}


def run():
    ratios = hw.table5_ratios()
    return {"ratios": ratios, "paper": PAPER,
            "rel_err": {k: abs(ratios[k] - PAPER[k]) / PAPER[k]
                        for k in PAPER}}


def main(verbose=True):
    out = run()
    if verbose:
        print("== Table V: TALU vs UMAC (28 nm) ==")
        for k, v in out["ratios"].items():
            print(f"  {k:16s} ours {v:7.2f}x   paper {PAPER[k]:6.2f}x   "
                  f"err {100 * out['rel_err'][k]:.1f}%")
    return out


if __name__ == "__main__":
    main()
