"""Self-speculative decoding vs baseline greedy: acceptance rate and
target-model decode steps per emitted token.

Serves the same request set through the baseline ``ServingEngine`` and
the ``SpeculativeEngine`` (posit8 draft policy) at several gamma values
and both KV layouts, reporting per cell:

  * acceptance rate — accepted drafts / proposed drafts (how often the
    posit8 pass agrees with the target-precision argmax, the paper's
    "low-bitwidth posit keeps accuracy close" claim doing real work);
  * target steps/token — verify passes per emitted decode token.  < 1.0
    means the expensive target-precision datapath runs LESS than once
    per token: the speculative win.  The draft steps are posit8-cheap
    and reported separately;
  * stream identity — speculative greedy output must equal baseline
    greedy output token for token (bit-exact verify + rollback);
  * tokens/s for both engines (CPU reference numbers on this container).

Acceptance target (ISSUE 3): identical streams and < 1.0 target
steps/token at gamma >= 2.

Every cell (and each layout's baseline) carries a ``stage_breakdown``
from the span tracer (:mod:`repro.obs`): per-stage dispatch vs
device-sync seconds (draft stages prefixed ``draft.``), host overhead,
and the fraction of wall attributed — the data behind ROADMAP direction
1's "why is speculative wall-clock slower" question.  Set
``REPRO_TRACE=1`` to also write the sweep's Chrome trace to
``results/BENCH_speculative.trace.json``.

Writes the machine-readable artifact ``benchmarks/results/
BENCH_speculative.json``.

  PYTHONPATH=src python -m benchmarks.run speculative
"""
from __future__ import annotations

import json
import os
from time import perf_counter

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.obs import EnergyAccountant, Tracer, stage_breakdown
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.serve.speculative import SpeculativeEngine

GAMMAS = (2, 4)
LAYOUTS = ("ring", "paged")
KV_FORMAT = "posit8"
MAX_BATCH, MAX_LEN, PAGE_SIZE, MAX_NEW, N_REQ = 2, 64, 8, 10, 4
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(4, 13))),
                    max_new=MAX_NEW)
            for i in range(N_REQ)]


def _serve(engine_f, cfg, tracer):
    eng = engine_f()
    reqs = _requests(cfg)
    since = tracer.self_times()
    t0 = perf_counter()
    stats = eng.serve(reqs)
    wall = perf_counter() - t0
    stats["wall_s"] = wall
    stats["tok_per_s"] = stats["tokens"] / max(wall, 1e-9)
    stats["stage_breakdown"] = stage_breakdown(tracer, wall, since=since)
    # fresh engine per cell -> fresh registry: the cumulative breakdown
    # IS the cell's energy (per-stage pJ table + this cell's call counts)
    stats["energy_breakdown"] = EnergyAccountant(eng).breakdown()
    return [r.out_tokens for r in reqs], stats


def run():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # one tracer across the whole sweep: per-cell deltas via since=
    # snapshots, one Chrome trace covering every cell at the end
    tracer = Tracer(capacity=1 << 18, enabled=True)
    out = {"shape": {"max_batch": MAX_BATCH, "max_len": MAX_LEN,
                     "page_size": PAGE_SIZE, "max_new": MAX_NEW,
                     "requests": N_REQ, "kv_format": KV_FORMAT},
           "cells": {}, "baselines": {}}
    for layout in LAYOUTS:
        scfg = ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                           kv_format=KV_FORMAT, kv_layout=layout,
                           page_size=PAGE_SIZE)
        base_out, base_stats = _serve(
            lambda: ServingEngine(cfg, params, scfg, tracer=tracer), cfg,
            tracer)
        out["baselines"][layout] = {
            "tok_per_s": round(base_stats["tok_per_s"], 1),
            "stage_breakdown": base_stats["stage_breakdown"],
            "energy_breakdown": base_stats["energy_breakdown"]}
        target_step_pj = (base_stats["energy_breakdown"]["stages"]
                          .get("generate", {}).get("pj_per_call"))
        for gamma in GAMMAS:
            spec_out, s = _serve(
                lambda: SpeculativeEngine(cfg, params, scfg, gamma=gamma,
                                          tracer=tracer),
                cfg, tracer)
            decode_tokens = s["tokens"] - s["prefills"]
            cell = {
                "identical": spec_out == base_out,
                "acceptance_rate": round(
                    s["drafts_accepted"] / max(s["drafts_proposed"], 1), 4),
                "target_steps_per_token": round(
                    s["decode_steps"] / max(decode_tokens, 1), 4),
                "draft_steps_per_token": round(
                    s["draft_steps"] / max(decode_tokens, 1), 4),
                "spec_rounds": s["spec_rounds"],
                "tok_per_s": {"baseline": round(base_stats["tok_per_s"], 1),
                              "speculative": round(s["tok_per_s"], 1)},
                "stage_breakdown": s["stage_breakdown"],
                "energy_breakdown": s["energy_breakdown"],
            }
            # the speculative win in energy terms: one posit8-weight
            # draft step must cost less than one target-precision decode
            # step of the same layout's baseline engine (the spec engine
            # itself never runs a bare `generate`; verify replaces it)
            draft_step_pj = (s["energy_breakdown"]["stages"]
                            .get("draft.generate", {}).get("pj_per_call"))
            cell["energy"] = {
                "draft_step_pj": draft_step_pj,
                "target_step_pj": target_step_pj,
                "joules_per_token":
                    s["energy_breakdown"]["joules_per_token"],
                "draft_below_target": bool(
                    draft_step_pj is not None and target_step_pj is not None
                    and draft_step_pj < target_step_pj)}
            out["cells"][f"{layout}_gamma{gamma}"] = cell
    cells = out["cells"].values()
    out["all_identical"] = all(c["identical"] for c in cells)
    out["best_target_steps_per_token"] = min(
        c["target_steps_per_token"] for c in cells)
    out["draft_energy_below_target"] = all(
        c["energy"]["draft_below_target"] for c in cells)
    if os.environ.get("REPRO_TRACE"):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, "BENCH_speculative.trace.json")
        tracer.write_chrome_trace(path)
        out["trace_file"] = os.path.basename(path)
    return out


def main(verbose=True):
    out = run()
    if verbose:
        sh = out["shape"]
        print(f"== Self-speculative decoding (batch={sh['max_batch']}, "
              f"max_new={sh['max_new']}, kv={sh['kv_format']}; "
              f"CPU reference) ==")
        print(f"{'cell':>14s} {'ident':>6s} {'accept':>7s} "
              f"{'tgt steps/tok':>14s} {'draft steps/tok':>16s} "
              f"{'draft/tgt uJ':>13s} {'uJ/tok':>8s}")
        for name, c in out["cells"].items():
            en = c["energy"]
            dt = (f"{en['draft_step_pj'] * 1e-6:.0f}/"
                  f"{en['target_step_pj'] * 1e-6:.0f}"
                  if en["draft_step_pj"] and en["target_step_pj"] else "-")
            jpt = en["joules_per_token"]
            print(f"{name:>14s} {str(c['identical']):>6s} "
                  f"{c['acceptance_rate']:>7.2f} "
                  f"{c['target_steps_per_token']:>14.2f} "
                  f"{c['draft_steps_per_token']:>16.2f} "
                  f"{dt:>13s} "
                  f"{jpt * 1e6 if jpt else 0:>8.1f}")
        print(f"  draft step below target step energy: "
              f"{out['draft_energy_below_target']}")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "BENCH_speculative.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
