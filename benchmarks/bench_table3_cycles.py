"""Table III reproduction: TALU cycle counts per format/operation.

Runs the bit-accurate cycle-level TALU simulator (core/talu.py) and compares
its structural cycle counts against the paper's Table III.  The simulator's
micro-op schedules are reconstructions constrained by the paper's datapath
(two 8-wide Q clusters, 2-cycle ADD/XOR, 1-cycle COMP/AND/OR/decode plane,
single-cycle shifter/LUT/combiner) — matching counts validate that the
published latencies are *achievable* on the published datapath.
"""
from __future__ import annotations

from repro.core.formats import (POSIT8_0, POSIT8_2, POSIT16_0, POSIT16_2)
from repro.core.talu import TABLE3, TALU

ROWS = [
    ("P(8,0)", POSIT8_0, "posit"), ("P(8,2)", POSIT8_2, "posit"),
    ("P(16,0)", POSIT16_0, "posit"), ("P(16,2)", POSIT16_2, "posit"),
    ("FP8", 8, "fp"), ("FP16", 16, "fp"),
    ("INT4", 4, "int"), ("INT8", 8, "int"), ("INT16", 16, "int"),
]


def run():
    talu = TALU()
    out = []
    for cfg_name, fmt, kind in ROWS:
        row = {"config": cfg_name}
        for opname, col in (("decode", "decode"), ("mul", "mul"),
                            ("add", "add")):
            paper = TABLE3[(cfg_name, col)]
            if kind == "posit":
                got = (talu.measure(f"posit_{opname}", fmt=fmt)
                       if opname != "decode"
                       else talu.measure("posit_decode", fmt=fmt))
            elif kind == "fp":
                got = 0 if opname == "decode" else talu.measure(
                    f"fp_{opname}", bits=fmt)
            else:
                got = 0 if opname == "decode" else talu.measure(
                    f"int_{opname}", bits=fmt)
            row[col] = got
            row[col + "_paper"] = paper
        out.append(row)
    return out


def main(verbose=True):
    rows = run()
    n_exact = sum(r[c] == r[c + "_paper"] for r in rows
                  for c in ("decode", "mul", "add"))
    n_total = 3 * len(rows)
    if verbose:
        print("== Table III: TALU cycles (ours vs paper) ==")
        print(f"{'config':9s} {'decode':>12s} {'mul':>12s} {'add':>12s}")
        for r in rows:
            print(f"{r['config']:9s} "
                  f"{r['decode']:>5d}/{r['decode_paper']:<6d} "
                  f"{r['mul']:>5d}/{r['mul_paper']:<6d} "
                  f"{r['add']:>5d}/{r['add_paper']:<6d}")
        print(f"exact matches: {n_exact}/{n_total}")
    return {"rows": rows, "exact": n_exact, "total": n_total}


if __name__ == "__main__":
    main()
