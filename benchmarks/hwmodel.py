"""Analytical hardware model reproducing the paper's evaluation tables.

The paper's numbers are ASIC synthesis/P&R results — not runnable in
software — so this module encodes the published per-design constants
(Tables IV & V) plus the Stillmaker-Baas technology-scaling method the
paper uses [26], and re-derives every ratio the paper claims.  Fitted
parameters (where the paper's microarchitectural detail is unpublished)
are explicit, documented, and bounded:

* ``UMAC_V_UTILIZATION`` — UMAC-V sustained fraction of peak on 3x3 MATMUL
  kernels.  The paper reports only the end ratio (0.93x throughput); the
  structural bounds are [0.16 (full 6-stage drain per kernel), 1.0
  (perfect pipelining)]; 0.41 reproduces Table VI.
* ``RISCY_POWER_MW``     — RISCY core power added to both vector systems.
  89 mW reproduces Table VI's 1.98x energy efficiency and sits inside the
  published RISCY envelope (~30-120 mW at 28 nm, [11]).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

# ---------------------------------------------------------------------------
# Published design points (paper Tables IV & V, all scaled to 28 nm)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    name: str
    node_nm: int                 # original technology node
    freq_ghz: float
    bits: tuple
    delay_ns: tuple              # per bits entry
    area_mm2: tuple
    power_mw: tuple
    pdp_pj: tuple
    pow_density: tuple           # mW/mm^2
    formats: str = "posit"


TALU = DesignPoint(
    name="TALU", node_nm=28, freq_ghz=2.0, bits=(8, 16, 32),
    delay_ns=(21.5, 24, 25.5), area_mm2=(0.0026,) * 3,
    power_mw=(1.81,) * 3, pdp_pj=(38.9, 43.44, 46.15),
    pow_density=(696.15,) * 3, formats="posit+fp+int")

VMULT = DesignPoint(
    name="VMULT", node_nm=90, freq_ghz=0.4, bits=(8, 16, 32),
    delay_ns=(0.71,) * 3, area_mm2=(0.014,) * 3, power_mw=(42.94,) * 3,
    pdp_pj=(30.7,) * 3, pow_density=(2878.62,) * 3)

DFMA = DesignPoint(
    name="DFMA", node_nm=45, freq_ghz=0.8, bits=(8, 16, 32),
    delay_ns=(0.75, 0.93, 1.12), area_mm2=(0.0044, 0.0145, 0.0435),
    power_mw=(13.77, 32.4, 76.95), pdp_pj=(10.28, 30.24, 86.18),
    pow_density=(3155.0, 2227.5, 1767.1))

FUSED_MAC = DesignPoint(
    name="FusedMAC", node_nm=45, freq_ghz=1.0, bits=(8, 16, 32),
    delay_ns=(0.50, 0.47, 0.63), area_mm2=(0.0023, 0.006, 0.015),
    power_mw=(3.92, 9.5, 27.44), pdp_pj=(1.97, 4.55, 17.41),
    pow_density=(1724.97, 1609.28, 1829.52))

UMAC = DesignPoint(
    name="UMAC", node_nm=28, freq_ghz=0.667, bits=(8, 16, 32),
    delay_ns=(1.5,) * 3, area_mm2=(0.0515,) * 3, power_mw=(99.0,) * 3,
    pdp_pj=(148.5,) * 3, pow_density=(1941.17,) * 3, formats="posit+fp")

POSIT_ONLY = (VMULT, DFMA, FUSED_MAC)


# ---------------------------------------------------------------------------
# Serving energy-model hooks (repro.obs.energy)
# ---------------------------------------------------------------------------

#: Modeled off-chip memory access energy, pJ/byte.  LPDDR4-class DRAM at
#: the paper's 28 nm edge deployment point: published LPDDR4/LPDDR4X
#: figures cluster around 15-25 pJ/byte device+PHY (vs ~2 pJ/byte for
#: on-package HBM2 and >50 pJ/byte for DDR3) — 20 pJ/byte is the
#: conventional round number for edge-SoC energy models.  Every
#: joules/token figure this repo reports scales linearly in this
#: constant, so it is a single documented knob, not a fit.
DRAM_PJ_PER_BYTE = 20.0


def pj_per_mac(bits: int, dp: DesignPoint = TALU) -> float:
    """Per-MAC energy (pJ) at an operand bitwidth, from the design
    point's published PDP row (Table IV: TALU 38.9/43.44/46.15 pJ at
    8/16/32 bit).  Bitwidths snap UP to the next supported class — a
    posit(4,1) MAC still occupies the 8-bit datapath slice."""
    idx = 0 if bits <= dp.bits[0] else (1 if bits <= dp.bits[1] else 2)
    return dp.pdp_pj[idx]


# ---------------------------------------------------------------------------
# Stillmaker-Baas scaling [26]: area ~ s^2, delay ~ s, power ~ s * v^2
# (general-purpose fits; the paper applies this to normalize 90/45 nm
#  designs to 28 nm — Table IV carries the POST-scaling values, so this
#  function is used for consistency checks / original-node back-projection)
# ---------------------------------------------------------------------------

def scale_to(node_from_nm: float, node_to_nm: float) -> Dict[str, float]:
    s = node_to_nm / node_from_nm
    return {"area": s ** 2, "delay": s, "power": s}   # iso-V_dd first order


def backproject(dp: DesignPoint, metric: str, idx: int) -> float:
    """Original-node value implied by the paper's 28 nm-scaled number."""
    f = scale_to(dp.node_nm, 28.0)[metric]
    val = getattr(dp, {"area": "area_mm2", "delay": "delay_ns",
                       "power": "power_mw"}[metric])[idx]
    return val / f


# ---------------------------------------------------------------------------
# Table V ratios (TALU vs UMAC) — the headline claims
# ---------------------------------------------------------------------------

def table5_ratios() -> Dict[str, float]:
    pdp_talu = sum(TALU.pdp_pj) / len(TALU.pdp_pj)
    return {
        "area_x": UMAC.area_mm2[0] / TALU.area_mm2[0],          # 19.8x
        "power_x": UMAC.power_mw[0] / TALU.power_mw[0],         # 54.6x
        "pdp_x": UMAC.pdp_pj[0] / pdp_talu,                     # 3.47x
        "pow_density_x": UMAC.pow_density[0] / TALU.pow_density[0],  # 2.76x
    }


def table4_ratios() -> Dict[str, Dict[str, float]]:
    """TALU vs each posit-only design (paper: 5.4-16.7x area,
    15.16-42.5x power (the '2x to 43x' §IV text includes FusedMAC-8),
    2.53-4.13x power density)."""
    out = {}
    for dp in POSIT_ONLY:
        out[dp.name] = {
            "area_x": max(dp.area_mm2) / TALU.area_mm2[0]
            if dp.name != "VMULT" else dp.area_mm2[0] / TALU.area_mm2[0],
            "area_x_min": min(dp.area_mm2) / TALU.area_mm2[0],
            "power_x": max(dp.power_mw) / TALU.power_mw[0],
            "power_x_min": min(dp.power_mw) / TALU.power_mw[0],
            "density_x": max(dp.pow_density) / TALU.pow_density[0],
            "density_x_min": min(dp.pow_density) / TALU.pow_density[0],
        }
    return out


# ---------------------------------------------------------------------------
# Table VI: equi-area TALU-V vs UMAC-V on 3x3 MATMUL (P(8,2))
# ---------------------------------------------------------------------------

RISCY_POWER_MW = 89.0        # fitted (see module docstring)
UMAC_V_UTILIZATION = 0.41    # fitted (see module docstring)

N_TALU_LANES = 128           # 1024-bit RF / 8-bit operands (paper §IV-D)
N_UMAC_UNITS = 6             # equi-area: 6 x 0.0515 ~= 128 x 0.0026 mm^2
UMAC_MACS_PER_CYCLE = 4      # "8 x 4 produced per cycle" (8-bit mode)


def talu_v_throughput(mul_cyc: int = 19, add_cyc: int = 23,
                      kernel_macs: int = 27) -> float:
    """3x3 MATMUL kernels/s: 128 SIMD lanes, each MAC = mul+add cycles."""
    macs_per_s = N_TALU_LANES * TALU.freq_ghz * 1e9 / (mul_cyc + add_cyc)
    return macs_per_s / kernel_macs


def umac_v_throughput(kernel_macs: int = 27,
                      utilization: float = UMAC_V_UTILIZATION) -> float:
    macs_per_s = (N_UMAC_UNITS * UMAC_MACS_PER_CYCLE * UMAC.freq_ghz * 1e9
                  * utilization)
    return macs_per_s / kernel_macs


def table6_ratios() -> Dict[str, float]:
    thr_t = talu_v_throughput()
    thr_u = umac_v_throughput()
    p_t = N_TALU_LANES * TALU.power_mw[0] + RISCY_POWER_MW       # mW
    p_u = N_UMAC_UNITS * UMAC.power_mw[0] + RISCY_POWER_MW
    eff_t = thr_t / (p_t * 1e-3)     # kernels / J
    eff_u = thr_u / (p_u * 1e-3)
    return {
        "throughput_x": thr_t / thr_u,                  # paper: 0.93x
        "energy_eff_x": eff_t / eff_u,                  # paper: 1.98x
        "talu_v_kernels_per_s": thr_t,
        "umac_v_kernels_per_s": thr_u,
        "talu_v_power_mw": p_t, "umac_v_power_mw": p_u,
        "equi_area_talu_mm2": N_TALU_LANES * TALU.area_mm2[0],
        "equi_area_umac_mm2": N_UMAC_UNITS * UMAC.area_mm2[0],
    }


def table6_sensitivity() -> Dict[str, Dict[str, float]]:
    """How the Table VI ratios move across the fitted-parameter bounds."""
    out = {}
    for util in (0.16, 0.41, 1.0):
        thr_ratio = talu_v_throughput() / umac_v_throughput(utilization=util)
        out[f"util={util}"] = {"throughput_x": thr_ratio}
    for p_riscy in (0.0, 89.0, 150.0):
        thr_t, thr_u = talu_v_throughput(), umac_v_throughput()
        p_t = N_TALU_LANES * TALU.power_mw[0] + p_riscy
        p_u = N_UMAC_UNITS * UMAC.power_mw[0] + p_riscy
        out[f"riscy={p_riscy}mW"] = {
            "energy_eff_x": (thr_t / p_t) / (thr_u / p_u)}
    return out
