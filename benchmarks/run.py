"""Benchmark harness entry point: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table3 ... # subset

Writes machine-readable results to benchmarks/results/*.json and prints
the ``name,us_per_call,derived`` summary CSV expected by the harness.
"""
from __future__ import annotations

import json
import os
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _entry(name):
    if name == "table3":
        from . import bench_table3_cycles as m
    elif name == "table4":
        from . import bench_table4_posit_designs as m
    elif name == "table5":
        from . import bench_table5_umac as m
    elif name == "table6":
        from . import bench_table6_vector as m
    elif name == "accuracy":
        from . import bench_accuracy as m
    elif name == "roofline":
        from . import roofline as m
    elif name == "kernels":
        from . import bench_kernels as m
    elif name == "kv_cache":
        from . import bench_kv_cache as m
    elif name == "paged_kv":
        from . import bench_paged_kv as m
    elif name == "speculative":
        from . import bench_speculative as m
    elif name == "serving":
        from . import bench_serving as m
    else:
        raise KeyError(name)
    return m


ALL = ("table3", "table4", "table5", "table6", "accuracy", "kernels",
       "kv_cache", "paged_kv", "speculative", "serving", "roofline")


def main():
    names = sys.argv[1:] or ALL
    os.makedirs(RESULTS_DIR, exist_ok=True)
    csv = ["name,us_per_call,derived"]
    for name in names:
        t0 = time.time()
        out = _entry(name).main(verbose=True)
        dt_us = (time.time() - t0) * 1e6
        # canonical per-bench artifact; the modules write the same file
        # themselves, so this never forks a stale "{name}.json" duplicate
        path = os.path.join(RESULTS_DIR, f"BENCH_{name}.json")
        try:
            json.dump(out, open(path, "w"), indent=1, default=str)
        except TypeError:
            pass
        derived = ""
        if name == "table3":
            derived = f"exact={out['exact']}/{out['total']}"
        elif name == "table5":
            derived = (f"area={out['ratios']['area_x']:.1f}x;"
                       f"power={out['ratios']['power_x']:.1f}x")
        elif name == "table6":
            derived = (f"thr={out['ratios']['throughput_x']:.2f}x;"
                       f"eff={out['ratios']['energy_eff_x']:.2f}x")
        elif name == "accuracy":
            derived = (f"p32_orders={out['matmul32']['orders_better']:.1f}")
        elif name == "roofline":
            derived = f"cells={out['n_ok']}/{out['n_cells']}"
        elif name == "kernels":
            derived = f"max_err={out['max_rel_err']:.1e}"
        elif name == "paged_kv":
            derived = f"live/ring_p8={out['live_vs_ring']['posit8']:.2f}"
        elif name == "speculative":
            derived = (f"ident={out['all_identical']};"
                       f"tgt_steps={out['best_target_steps_per_token']:.2f}")
        elif name == "serving":
            knee = out["loads"][-1]
            derived = (f"loads={len(out['loads'])};"
                       f"p99_ttft_ms={knee['ttft_ms']['p99']:.0f}")
        csv.append(f"{name},{dt_us:.0f},{derived}")
        print()
    print("\n".join(csv))


if __name__ == "__main__":
    main()
