"""KV-cache transprecision benchmark: HBM footprint, decode-step time and
accuracy deltas per ``kv_format`` across batch x context grids.

Default footprint shape is serving-realistic (head_dim = 64); per K/V
element (codes + amortized per-row f32 scale) that gives

    f32     4.00 B
    bf16    2.00 B   (baseline)
    posit16 2.06 B   (0.52x the f32 cache; same width as bf16 + scales)
    posit8  1.06 B   (0.53x bf16 / 0.27x f32; 8-bit information floor)
    posit4  0.56 B   (nibble-packed: 0.28x, <= 0.3x the bf16 baseline)

Timings on this container are CPU reference numbers (labelled as such;
the Pallas kernels target TPU); the accuracy section runs the real
``ServingEngine`` greedy loop per format against the f32 cache on the
quickstart-style prompt set.

  PYTHONPATH=src python -m benchmarks.run kv_cache
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.transprecision import KV_FORMATS
from repro.models import lm
from repro.models.serve_model import decode_step, init_cache
from repro.serve.engine import Request, ServeConfig, ServingEngine

FORMATS = ("f32", "bf16", "posit16", "posit8", "posit4")
# default footprint shape: (batch, context) grid x serving-like heads
GRID = ((1, 128), (4, 512), (16, 2048))
NKV, HD = 4, 64
DEFAULT_SHAPE = (4, 512)


def cache_bytes(batch: int, ctx: int, kv_format: str) -> int:
    """Exact K+V ring bytes for one attention layer at (batch, ctx)."""
    spec = KV_FORMATS[kv_format]
    n = batch * ctx * NKV
    if spec.is_posit:
        code_ch = HD // 2 if spec.packed else HD
        per = code_ch * jnp.dtype(spec.fmt.storage_dtype).itemsize + 4  # +scale
    else:
        per = HD * jnp.dtype(spec.dtype).itemsize
    return 2 * n * per


def _engine(cfg, params, kv_format, max_len=64):
    return ServingEngine(cfg, params,
                         ServeConfig(max_batch=2, max_len=max_len,
                                     kv_format=kv_format))


def run():
    out = {"hbm_bytes": {}, "ratio_vs_bf16": {}, "ratio_vs_f32": {},
           "cpu_reference_decode_us": {}, "accuracy": {}}

    # --- footprint across the batch x context grid --------------------
    for b, ctx in GRID:
        for f in FORMATS:
            out["hbm_bytes"][f"{f}_b{b}_ctx{ctx}"] = cache_bytes(b, ctx, f)
    b, ctx = DEFAULT_SHAPE
    bf16 = cache_bytes(b, ctx, "bf16")
    f32 = cache_bytes(b, ctx, "f32")
    for f in FORMATS:
        cb = cache_bytes(b, ctx, f)
        out["ratio_vs_bf16"][f] = round(cb / bf16, 4)
        out["ratio_vs_f32"][f] = round(cb / f32, 4)

    # --- decode-step wall time (CPU reference) ------------------------
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.ones((2, 1), jnp.int32)
    for f in FORMATS:
        import dataclasses
        from repro.core.transprecision import BF16
        pol = dataclasses.replace(BF16, kv_format=f, name=f"bench_kv_{f}")
        cache = init_cache(cfg, 2, 64, policy=pol)
        step = jax.jit(lambda p, c, t, pol=pol: decode_step(p, c, t, cfg,
                                                            pol))
        logits, cache = step(params, cache, tok)       # compile + warm
        jax.block_until_ready(logits)
        t0 = time.time()
        for _ in range(5):
            logits, cache = step(params, cache, tok)
        jax.block_until_ready(logits)
        out["cpu_reference_decode_us"][f] = (time.time() - t0) / 5 * 1e6

    # --- accuracy deltas: engine greedy loop vs the f32 cache ---------
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
               for _ in range(4)]

    def serve(f):
        eng = _engine(cfg, params, f)
        reqs = [Request(uid=i, prompt=p, max_new=8)
                for i, p in enumerate(prompts)]
        eng.serve(reqs)
        return [r.out_tokens for r in reqs]

    ref_toks = serve("f32")
    for f in FORMATS:
        toks = ref_toks if f == "f32" else serve(f)
        flat_a = [t for seq in toks for t in seq]
        flat_b = [t for seq in ref_toks for t in seq]
        match = float(np.mean([a == b for a, b in zip(flat_a, flat_b)]))
        out["accuracy"][f] = {"greedy_match_vs_f32": round(match, 4)}
    return out


def main(verbose=True):
    out = run()
    if verbose:
        b, ctx = DEFAULT_SHAPE
        print(f"== KV-cache transprecision (default shape: batch={b}, "
              f"ctx={ctx}, nkv={NKV}, hd={HD}; per attention layer) ==")
        print(f"{'format':>8s} {'bytes':>12s} {'vs bf16':>8s} {'vs f32':>8s}"
              f" {'decode us (CPU ref)':>20s} {'greedy==f32':>12s}")
        for f in FORMATS:
            print(f"{f:>8s} {out['hbm_bytes'][f'{f}_b{b}_ctx{ctx}']:>12d} "
                  f"{out['ratio_vs_bf16'][f]:>8.3f} "
                  f"{out['ratio_vs_f32'][f]:>8.3f} "
                  f"{out['cpu_reference_decode_us'][f]:>20.0f} "
                  f"{out['accuracy'][f]['greedy_match_vs_f32']:>12.2f}")
    return out


if __name__ == "__main__":
    main()
