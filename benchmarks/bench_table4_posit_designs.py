"""Table IV reproduction: TALU vs posit-only compute elements at 28 nm.

Re-derives every ratio the paper claims in its contributions list:
  "5.4x to 16.7x smaller area, 15.16x to 42.5x lower power and 2.53x to
   4.13x lower power density" (§I, 32-bit comparison per the text)
plus the §IV key-takeaway ranges (delay 22-50x worse, PDP 1.5-20x worse),
and back-projects the 28 nm-scaled numbers to each design's original node
via the Stillmaker-Baas equations the paper uses [26].
"""
from __future__ import annotations

from . import hwmodel as hw


def run():
    t = hw.TALU
    out = {"designs": {}, "claims": {}}
    for dp in hw.POSIT_ONLY:
        i32 = len(dp.bits) - 1            # 32-bit column
        out["designs"][dp.name] = {
            "area_x_32": dp.area_mm2[i32] / t.area_mm2[i32],
            "power_x_32": dp.power_mw[i32] / t.power_mw[i32],
            "density_x_32": dp.pow_density[min(i32, len(dp.pow_density) - 1)]
            / t.pow_density[0],
            "delay_x_32": t.delay_ns[i32] / dp.delay_ns[min(
                i32, len(dp.delay_ns) - 1)],
            "pdp_talu_worse_x_32": t.pdp_pj[i32] / dp.pdp_pj[min(
                i32, len(dp.pdp_pj) - 1)],
            "area_mm2_at_origin_node": hw.backproject(dp, "area", 0),
        }
    d = out["designs"]
    area_lo = min(v["area_x_32"] for v in d.values())
    area_hi = max(v["area_x_32"] for v in d.values())
    pow_lo = min(v["power_x_32"] for v in d.values())
    pow_hi = max(v["power_x_32"] for v in d.values())
    den_lo = min(v["density_x_32"] for v in d.values())
    den_hi = max(v["density_x_32"] for v in d.values())
    out["claims"] = {
        "area_range_x": (area_lo, area_hi),          # paper: 5.4 .. 16.7
        "power_range_x": (pow_lo, pow_hi),           # paper: 15.16 .. 42.5
        "density_range_x": (den_lo, den_hi),         # paper: 2.53 .. 4.13
        "paper_area_range": (5.4, 16.7),
        "paper_power_range": (15.16, 42.5),
        "paper_density_range": (2.53, 4.13),
    }
    return out


def main(verbose=True):
    out = run()
    if verbose:
        print("== Table IV: TALU vs posit-only designs (32-bit, 28 nm) ==")
        for name, v in out["designs"].items():
            print(f"  {name:9s} area {v['area_x_32']:6.2f}x  "
                  f"power {v['power_x_32']:6.2f}x  "
                  f"density {v['density_x_32']:5.2f}x  "
                  f"delay(TALU worse) {v['delay_x_32']:5.1f}x")
        c = out["claims"]
        print(f"  ranges: area {c['area_range_x'][0]:.1f}-"
              f"{c['area_range_x'][1]:.1f}x (paper 5.4-16.7), "
              f"power {c['power_range_x'][0]:.2f}-"
              f"{c['power_range_x'][1]:.1f}x (paper 15.16-42.5), "
              f"density {c['density_range_x'][0]:.2f}-"
              f"{c['density_range_x'][1]:.2f}x (paper 2.53-4.13)")
    return out


if __name__ == "__main__":
    main()
