"""Accuracy claims of §II: posit's tapered precision on ML-like data.

1. §II worked example: 0.00024 encodes in P(8,2) with ~1.6% error while
   8-bit floats ((e=3,m=4)/(e=4,m=3)) underflow to zero.
2. [19]-style matmul: n-bit posit vs same-n float MSE for 32x32 matmuls
   over U[-1,1] with per-MAC rounding — posit16 beats fp16 by >=1 order;
   posit32 beats fp32 by ~2 orders ("two orders lower" claim).
3. Value clustering: quantization MSE of posit8/int8/fp8 across value
   scales — posit wins where values cluster near 0 (weights/activations).
"""
from __future__ import annotations

import numpy as np

from repro.core import posit_ref
from repro.core.formats import (FP8_E4M3, INT8, POSIT8_2, POSIT16_2,
                                POSIT32_2, get)
from repro.core.quant import quantization_mse


def example_000024():
    x = 0.00024
    p = posit_ref.encode(x, 8, 2)
    dec = float(posit_ref.to_fraction(p, 8, 2))
    posit_err = abs(dec - x) / x
    # 8-bit minifloats with subnormals: (e=3,m=4) min subnormal 2^-2/16;
    # (e=4,m=3) min subnormal 2^-6/8 = 2^-9 ~ 0.00195 >> 0.00024 -> 0
    def fp_round(x, e, m):
        bias = 2 ** (e - 1) - 1
        minn = 2.0 ** (1 - bias - m)          # smallest subnormal
        q = np.round(x / minn) * minn
        return float(q)
    fp_vals = {f"fp8_e{e}m{m}": fp_round(x, e, m) for e, m in ((3, 4), (4, 3))}
    return {"posit_code": p, "posit_value": dec,
            "posit_rel_err": posit_err, "fp8": fp_vals}


def _matmul_mse(n_bits: int, es: int, fp_dtype, trials=4, dim=32, seed=0):
    """Per-MAC-rounded matmul MSE vs float64 reference.

    The posit side runs on CODES through the exact integer oracle
    (posit_ref.mul / posit_ref.add = exact rational op + RNE encode), i.e.
    true posit arithmetic, not float emulation."""
    rng = np.random.default_rng(seed)
    mses_p, mses_f = [], []
    for _ in range(trials):
        a = rng.uniform(-1, 1, (dim, dim))
        b = rng.uniform(-1, 1, (dim, dim))
        ref = a @ b
        ac = [[posit_ref.encode(v, n_bits, es) for v in row] for row in a]
        bc = [[posit_ref.encode(v, n_bits, es) for v in row] for row in b]

        def acc_posit(i, j):
            s = 0
            for k in range(dim):
                s = posit_ref.add(
                    s, posit_ref.mul(ac[i][k], bc[k][j], n_bits, es),
                    n_bits, es)
            return posit_ref.to_float(s, n_bits, es)

        out_p = np.array([[acc_posit(i, j) for j in range(dim)]
                          for i in range(dim)])
        af, bf = a.astype(fp_dtype), b.astype(fp_dtype)
        out_f = np.zeros((dim, dim), fp_dtype)
        for k in range(dim):        # per-MAC rounding in the float width
            out_f = (out_f + (af[:, k:k + 1] * bf[k:k + 1, :]).astype(
                fp_dtype)).astype(fp_dtype)
        mses_p.append(np.mean((out_p - ref) ** 2))
        mses_f.append(np.mean((out_f.astype(np.float64) - ref) ** 2))
    return float(np.mean(mses_p)), float(np.mean(mses_f))


def matmul_mse_16():
    return _matmul_mse(16, 2, np.float16, trials=2)


def matmul_mse_32():
    return _matmul_mse(32, 2, np.float32, trials=1, dim=16)


def clustering():
    rng = np.random.default_rng(0)
    out = {}
    for scale in (1.0, 0.1, 0.02):
        w = (rng.standard_normal(4096) * scale).astype(np.float32)
        out[f"sigma={scale}"] = {
            "posit8_2": float(quantization_mse(w, POSIT8_2)),
            "int8": float(quantization_mse(w, INT8)),
            "fp8_e4m3": float(quantization_mse(w, FP8_E4M3)),
        }
    return out


def run():
    ex = example_000024()
    p16, f16 = matmul_mse_16()
    p32, f32_ = matmul_mse_32()
    return {
        "example_000024": ex,
        "matmul16": {"posit16_mse": p16, "fp16_mse": f16,
                     "orders_better": float(np.log10(f16 / p16))},
        "matmul32": {"posit32_mse": p32, "fp32_mse": f32_,
                     "orders_better": float(np.log10(f32_ / p32))},
        "clustering": clustering(),
    }


def main(verbose=True):
    out = run()
    if verbose:
        ex = out["example_000024"]
        print("== §II example: x=0.00024 ==")
        print(f"  P(8,2) code=0b{ex['posit_code']:08b} -> "
              f"{ex['posit_value']:.6f} (rel err "
              f"{100 * ex['posit_rel_err']:.1f}%, paper: 1.6%)")
        print(f"  8-bit floats: {ex['fp8']} (paper: underflow to 0)")
        m = out["matmul16"]
        print(f"== 32x32 matmul MSE ==  posit16 {m['posit16_mse']:.3e} vs "
              f"fp16 {m['fp16_mse']:.3e} ({m['orders_better']:.1f} orders)")
        m = out["matmul32"]
        print(f"  posit32 {m['posit32_mse']:.3e} vs fp32 "
              f"{m['fp32_mse']:.3e} ({m['orders_better']:.1f} orders, "
              f"paper: ~2)")
        print("== quantization MSE by value scale ==")
        for k, v in out["clustering"].items():
            print(f"  {k}: " + "  ".join(f"{f}={e:.2e}"
                                         for f, e in v.items()))
    return out


if __name__ == "__main__":
    main()
