"""Pallas kernel micro-benchmarks (interpret mode on CPU = correctness +
reference timings; the BlockSpec tiling targets TPU v5e VMEM).

Reports decode/encode/matmul wall-times (CPU reference, labelled as such)
and max relative error of posit_matmul vs the pure-jnp oracle.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import POSIT8_2, POSIT16_2
from repro.kernels import ref
from repro.kernels.ops import posit_decode, posit_encode, posit_matmul


def _time(fn, *args, reps=3):
    fn(*args)  # warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def run():
    rng = np.random.default_rng(0)
    out = {"cpu_reference_timings_us": {}, "max_rel_err": 0.0}
    for fmt in (POSIT8_2, POSIT16_2):
        codes = rng.integers(0, 1 << fmt.bits, (256, 256)).astype(
            fmt.np_storage_dtype)
        us = _time(lambda c: posit_decode(c, fmt, interpret=True), codes)
        out["cpu_reference_timings_us"][f"decode_{fmt.name}_256x256"] = us
        x = rng.standard_normal((256, 256)).astype(np.float32)
        us = _time(lambda v: posit_encode(v, fmt, interpret=True), x)
        out["cpu_reference_timings_us"][f"encode_{fmt.name}_256x256"] = us

        a = rng.standard_normal((128, 256)).astype(np.float32)
        w = rng.integers(0, 1 << fmt.bits, (256, 192)).astype(
            fmt.np_storage_dtype)
        got = posit_matmul(a, w, fmt, blocks=(64, 64, 64), interpret=True)
        want = a @ np.asarray(ref.posit_decode_ref(w, fmt))
        want = np.nan_to_num(want)
        got = np.nan_to_num(np.asarray(got))
        denom = np.maximum(np.abs(want), 1e-3)
        out["max_rel_err"] = max(out["max_rel_err"],
                                 float(np.max(np.abs(got - want) / denom)))
    return out


def main(verbose=True):
    out = run()
    if verbose:
        print("== Pallas kernels (interpret-mode CPU reference) ==")
        for k, v in out["cpu_reference_timings_us"].items():
            print(f"  {k}: {v:.0f} us")
        print(f"  posit_matmul max rel err vs oracle: "
              f"{out['max_rel_err']:.2e}")
    return out


if __name__ == "__main__":
    main()
