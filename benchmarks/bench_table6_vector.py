"""Table VI reproduction: equi-area RISCY+TALU-V vs RISCY+UMAC-V on 3x3
MATMUL kernels — throughput 0.93x, energy efficiency 1.98x.

The TALU-V side is fully structural: 128 lanes x 2 GHz, P(8,2) MAC =
19 (mul) + 23 (add) cycles from the Table III simulator.  The UMAC-V side
carries one fitted utilization parameter (see hwmodel docstring); the
sensitivity sweep shows the ratio across its structural bounds.
"""
from __future__ import annotations

from repro.core.formats import POSIT8_2
from repro.core.talu import TALU, VectorUnit

from . import hwmodel as hw

PAPER = {"throughput_x": 0.93, "energy_eff_x": 1.98}


def run():
    talu = TALU()
    mul_c = talu.measure("posit_mul", fmt=POSIT8_2)
    add_c = talu.measure("posit_add", fmt=POSIT8_2)
    vu = VectorUnit()
    ratios = hw.table6_ratios()
    return {
        "simulator_cycles": {"posit_mul": mul_c, "posit_add": add_c,
                             "kernel_cycles_128lane":
                             vu.matmul_cycles(3, 3, 3, mul_c, add_c)},
        "ratios": ratios, "paper": PAPER,
        "rel_err": {k: abs(ratios[k] - PAPER[k]) / PAPER[k] for k in PAPER},
        "sensitivity": hw.table6_sensitivity(),
    }


def main(verbose=True):
    out = run()
    if verbose:
        print("== Table VI: equi-area TALU-V vs UMAC-V (3x3 MATMUL) ==")
        r = out["ratios"]
        print(f"  throughput  {r['throughput_x']:.3f}x (paper 0.93x)   "
              f"energy-eff {r['energy_eff_x']:.3f}x (paper 1.98x)")
        print(f"  equi-area: {r['equi_area_talu_mm2']:.3f} vs "
              f"{r['equi_area_umac_mm2']:.3f} mm^2;  "
              f"power {r['talu_v_power_mw']:.0f} vs "
              f"{r['umac_v_power_mw']:.0f} mW")
        print("  sensitivity:", out["sensitivity"])
    return out


if __name__ == "__main__":
    main()
