"""Pallas TPU kernels: posit-packed KV cache for serving decode.

The KV cache is the dominant HBM consumer during batched decode.  This
module stores the attention K/V rings as posit codes with a per-row
(token x head) power-of-two scale and keeps them packed end to end:

  write path  ``kv_append``       — one token's K/V rows are scaled,
      RNE-encoded and stored straight into the ring at ``pos % W``.  The
      ring position is a scalar-prefetch operand, so only the written
      (1, hd) row blocks ever move between HBM and VMEM (no full-ring
      read-modify-write), and the cache buffers are donated via
      ``input_output_aliases``.
  read path   ``decode_attention`` — fused decode-on-read flash decode:
      posit K/V tiles are decoded to f32 *in VMEM* right before the
      online-softmax inner loop (grid innermost over KV blocks, (m, l,
      acc) carried in VMEM scratch), mirroring the decode-in-VMEM
      structure of ``posit_matmul``.  Full-precision K/V never
      round-trips through HBM: HBM carries ``bits/16`` of the bf16
      baseline (plus one f32 scale per hd-row).

Sub-byte storage: P(4, 1) codes are nibble-packed two-per-byte along the
head dim (split-half layout: byte j holds elements j and j + hd/2, so
unpacking is a lane concatenation, not a gather).  With hd = 64 the cache
lands at ~0.28x the bf16 footprint; posit8 at ~0.53x.

Pure-jnp references (``encode_kv_rows`` / ``decode_kv_rows`` /
``decode_attention_ref``) share the scale rule and codec with the kernel
bodies, so the CPU serving path and the Pallas path are bit-identical on
the cache contents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import PositFormat
from .posit_decode import decode_tile
from .posit_encode import encode_tile

NEG_INF = -1e30
U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Shared codec helpers (pure jnp, Pallas-safe: used in kernel bodies and refs)
# ---------------------------------------------------------------------------

def row_pow2_scale(x):
    """Per-row power-of-two scale over the last axis: 2**floor(log2(mean|x|)).

    Exact (exponent-bit extraction, no transcendentals) so applying and
    removing the scale is lossless and the kernel/reference paths agree
    bit-for-bit.  Returns shape ``x.shape[:-1] + (1,)`` float32, >= 2^-98.
    """
    absx = jnp.abs(x.astype(jnp.float32))
    mean = jnp.maximum(jnp.mean(absx, axis=-1, keepdims=True), 1e-30)
    e = (jax.lax.bitcast_convert_type(mean, jnp.int32) >> 23) & 0xFF
    return jax.lax.bitcast_convert_type(e << 23, jnp.float32)


def pack_nibbles(codes):
    """(..., D) 4-bit codes (uint8, < 16) -> (..., D//2) split-half packed:
    byte j = codes[j] | codes[j + D/2] << 4."""
    d = codes.shape[-1]
    lo, hi = codes[..., : d // 2], codes[..., d // 2:]
    return lo | (hi << 4)


def unpack_nibbles(packed):
    """(..., D//2) packed bytes -> (..., D) 4-bit codes (lane concat)."""
    return jnp.concatenate([packed & 0xF, packed >> 4], axis=-1)


def encode_kv_rows(x, fmt: PositFormat, packed: bool = False):
    """Float rows (..., hd) -> (codes, scale (..., 1) f32).

    Per-row pow2 scale centres the posit tapered-precision region on the
    row's magnitude; codes are bit-exact RNE posit.  ``packed`` nibble-packs
    4-bit codes (hd must be even)."""
    scale = row_pow2_scale(x)
    codes = encode_tile(x.astype(jnp.float32) / scale, fmt)
    if packed:
        codes = pack_nibbles(codes)
    return codes, scale


def decode_kv_rows(codes, scale, fmt: PositFormat, packed: bool = False,
                   out_dtype=jnp.float32):
    """Inverse of ``encode_kv_rows``; scale broadcastable over the rows."""
    if packed:
        codes = unpack_nibbles(codes)
    v = decode_tile(codes, fmt, jnp.float32)
    return (v * scale).astype(out_dtype)


def code_channels(hd: int, fmt: PositFormat, packed: bool = False) -> int:
    """Last-axis size of the code buffer for hd float channels."""
    if packed:
        assert hd % 2 == 0, "nibble packing needs an even head dim"
        return hd // 2
    return hd


# ---------------------------------------------------------------------------
# kv_append: encode-on-write ring update (Pallas)
# ---------------------------------------------------------------------------

def kv_append(k_codes, k_scale, v_codes, v_scale, k_new, v_new, pos,
              fmt: PositFormat, *, packed: bool = False, interpret=None):
    """Encode-on-write ring append.

    k/v_codes: (B, W, H, Dc) posit codes; k/v_scale: (B, W, H) f32;
    k/v_new: (B, 1, H, hd) float; pos: int position, scalar (shared) or
    (B,) per-slot (mod W applied here).  Returns the four updated cache
    arrays (donated/aliased).  The T=1 case of ``kv_append_rows`` — one
    kernel to maintain, identical codec by construction."""
    return kv_append_rows(k_codes, k_scale, v_codes, v_scale, k_new, v_new,
                          pos, fmt, packed=packed, interpret=interpret)


def kv_append_ref(k_codes, k_scale, v_codes, v_scale, k_new, v_new, pos,
                  fmt: PositFormat, packed: bool = False):
    """Pure-jnp oracle for ``kv_append`` (the T=1 case of
    ``kv_append_rows_ref``).  ``pos`` scalar (shared) or (B,) per-slot."""
    return kv_append_rows_ref(k_codes, k_scale, v_codes, v_scale, k_new,
                              v_new, pos, fmt, packed)


# ---------------------------------------------------------------------------
# kv_append_rows: encode-on-write ring update for a T-token chunk (Pallas)
# ---------------------------------------------------------------------------

def _append_rows_kernel(idx_ref, kn_ref, vn_ref, kc_ref, ks_ref, vc_ref,
                        vs_ref, kco_ref, kso_ref, vco_ref, vso_ref, *,
                        fmt, packed):
    del idx_ref, kc_ref, ks_ref, vc_ref, vs_ref  # rows consumed by specs
    kc, ks = encode_kv_rows(kn_ref[0, 0, 0], fmt, packed)
    vc, vs = encode_kv_rows(vn_ref[0, 0, 0], fmt, packed)
    kco_ref[0, 0, 0] = kc
    vco_ref[0, 0, 0] = vc
    kso_ref[0, 0, 0] = ks[0]
    vso_ref[0, 0, 0] = vs[0]


@functools.partial(jax.jit, static_argnames=("fmt", "packed", "interpret"))
def kv_append_rows(k_codes, k_scale, v_codes, v_scale, k_new, v_new, pos,
                   fmt: PositFormat, *, packed: bool = False, interpret=None):
    """Encode-on-write ring append of a T-token chunk (speculative verify).

    Generalizes ``kv_append`` from one row to T consecutive rows per slot:
    k/v_new are (B, T, H, hd) floats and ``pos`` is the (B,) per-slot start
    position — token t of slot b lands at ring index (pos[b] + t) mod W.
    The (B, T) index matrix is a scalar-prefetch operand, so only the
    written (1, hd) row blocks move between HBM and VMEM and the cache
    buffers are donated, exactly like the single-row kernel."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, w, h, dc = k_codes.shape
    t, hd = k_new.shape[1], k_new.shape[-1]
    idx = (jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))[:, None]
           + jnp.arange(t, dtype=jnp.int32)[None, :]) % w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, t, h),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda i, ti, j, s: (i, ti, j, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda i, ti, j, s: (i, ti, j, 0)),
            pl.BlockSpec((1, 1, 1, dc), lambda i, ti, j, s: (i, s[i, ti], j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, ti, j, s: (i, s[i, ti], j)),
            pl.BlockSpec((1, 1, 1, dc), lambda i, ti, j, s: (i, s[i, ti], j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, ti, j, s: (i, s[i, ti], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, dc), lambda i, ti, j, s: (i, s[i, ti], j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, ti, j, s: (i, s[i, ti], j)),
            pl.BlockSpec((1, 1, 1, dc), lambda i, ti, j, s: (i, s[i, ti], j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, ti, j, s: (i, s[i, ti], j)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_append_rows_kernel, fmt=fmt, packed=packed),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(k_codes.shape, k_codes.dtype),
            jax.ShapeDtypeStruct(k_scale.shape, k_scale.dtype),
            jax.ShapeDtypeStruct(v_codes.shape, v_codes.dtype),
            jax.ShapeDtypeStruct(v_scale.shape, v_scale.dtype),
        ],
        # operand indices include the scalar-prefetch arg (index 0)
        input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
        interpret=interpret,
    )(idx, k_new, v_new, k_codes, k_scale, v_codes, v_scale)


def kv_append_rows_ref(k_codes, k_scale, v_codes, v_scale, k_new, v_new, pos,
                       fmt: PositFormat, packed: bool = False):
    """Pure-jnp oracle for ``kv_append_rows`` (same codec, XLA scatter)."""
    b, w = k_codes.shape[:2]
    t = k_new.shape[1]
    idx = (jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))[:, None]
           + jnp.arange(t, dtype=jnp.int32)[None, :]) % w
    rows = jnp.arange(b)[:, None]

    def wr(codes, scale, new):
        c, s = encode_kv_rows(new, fmt, packed)         # (B, T, H, Dc)
        codes = codes.at[rows, idx].set(c.astype(codes.dtype))
        scale = scale.at[rows, idx].set(s[..., 0])
        return codes, scale

    kc, ks = wr(k_codes, k_scale, k_new)
    vc, vs = wr(v_codes, v_scale, v_new)
    return kc, ks, vc, vs


# ---------------------------------------------------------------------------
# decode_attention: fused decode-on-read flash decode (Pallas)
# ---------------------------------------------------------------------------

def _decode_attn_kernel(len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                        o_ref, m_ref, l_ref, acc_ref, *, fmt, packed, bw, nw):
    ri = pl.program_id(0)          # fused (batch x kv-head) row
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode-on-read: posit codes -> f32 in VMEM, right before the MACs
    k = decode_tile(unpack_nibbles(kc_ref[0]) if packed else kc_ref[0],
                    fmt, jnp.float32) * ks_ref[0][:, None]       # (bw, hd)
    v = decode_tile(unpack_nibbles(vc_ref[0]) if packed else vc_ref[0],
                    fmt, jnp.float32) * vs_ref[0][:, None]
    q = q_ref[0].astype(jnp.float32)                              # (grp, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)       # (grp, bw)
    kpos = wi * bw + jnp.arange(bw)
    s = jnp.where((kpos < len_ref[ri])[None, :], s, NEG_INF)
    m_new = jnp.maximum(m_ref[...], s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(wi == nw - 1)
    def _finish():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("fmt", "packed", "block_w",
                                             "interpret"))
def decode_attention(q, k_codes, k_scale, v_codes, v_scale, cache_len,
                     fmt: PositFormat, *, packed: bool = False,
                     block_w: int = 128, interpret=None):
    """Fused one-token GQA attention over a posit-packed ring.

    q: (B, 1, nh, hd); k/v_codes: (B, W, nkv, Dc); k/v_scale: (B, W, nkv);
    cache_len: count of valid ring entries, scalar (shared) or (B,)
    per-slot.  Online softmax over KV blocks of ``block_w`` with
    decode-in-VMEM.  Returns (B, 1, nh, hd)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, w, nkv, dc = k_codes.shape
    nh, hd = q.shape[2], q.shape[3]
    grp = nh // nkv
    bw = min(block_w, w)
    pw = -w % bw
    # relayout to (B*nkv, ...) rows; the pad region is masked by cache_len<=W
    qg = (q.reshape(b, nkv, grp, hd) * (hd ** -0.5)).reshape(b * nkv, grp, hd)

    def rows(codes, scale):
        c = jnp.transpose(codes, (0, 2, 1, 3)).reshape(b * nkv, w, dc)
        s = jnp.transpose(scale, (0, 2, 1)).reshape(b * nkv, w)
        if pw:
            c = jnp.pad(c, ((0, 0), (0, pw), (0, 0)))
            s = jnp.pad(s, ((0, 0), (0, pw)), constant_values=1.0)
        return c, s

    kc, ks = rows(k_codes, k_scale)
    vc, vs = rows(v_codes, v_scale)
    nw = kc.shape[1] // bw
    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, fmt=fmt, packed=packed,
                          bw=bw, nw=nw),
        grid=(b * nkv, nw),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, grp, hd), lambda i, wi: (i, 0, 0)),
            pl.BlockSpec((1, bw, dc), lambda i, wi: (i, wi, 0)),
            pl.BlockSpec((1, bw), lambda i, wi: (i, wi)),
            pl.BlockSpec((1, bw, dc), lambda i, wi: (i, wi, 0)),
            pl.BlockSpec((1, bw), lambda i, wi: (i, wi)),
        ],
        out_specs=pl.BlockSpec((1, grp, hd), lambda i, wi: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nkv, grp, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((grp, 1), jnp.float32),
                        pltpu.VMEM((grp, 1), jnp.float32),
                        pltpu.VMEM((grp, hd), jnp.float32)],
        interpret=interpret,
    )(jnp.repeat(jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,)),
                 nkv), qg, kc, ks, vc, vs)
    return out.reshape(b, nkv, grp, hd).reshape(b, 1, nh, hd).astype(q.dtype)


def decode_attention_ref(q, k_codes, k_scale, v_codes, v_scale, cache_len,
                         fmt: PositFormat, packed: bool = False):
    """Pure-jnp oracle: decode the whole ring, dense masked softmax.
    ``cache_len`` scalar (shared) or (B,) per-slot."""
    b, w, nkv, _ = k_codes.shape
    nh, hd = q.shape[2], q.shape[3]
    grp = nh // nkv
    k = decode_kv_rows(k_codes, k_scale[..., None], fmt, packed)
    v = decode_kv_rows(v_codes, v_scale[..., None], fmt, packed)
    qg = q.reshape(b, 1, nkv, grp, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k)
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    s = jnp.where((jnp.arange(w)[None, :] < cl[:, None])
                  [:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(b, 1, nh, hd).astype(q.dtype)
