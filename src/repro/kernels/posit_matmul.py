"""Pallas TPU kernel: activations x posit-packed weights matmul.

The flagship TPU adaptation of the paper: TALU performs posit arithmetic in
the ALU; the TPU-native equivalent streams 8/16-bit posit *storage* through
HBM and decodes tiles in VMEM right before the MXU consumes them:

    HBM:  W packed posit8 (1 byte/param)           [bandwidth term /2..4]
    VMEM: decode_tile (VPU compares/shifts, Alg.1) [hidden under MXU time]
    MXU:  f32-accumulated dot per (bm, bk)x(bk, bn) block

Grid is (M/bm, N/bn, K/bk) with K innermost; the f32 accumulator lives in
the output block across K steps.  Per-output-channel (or scalar) scales fold
in after the last K step, so posit exponent-bias/int scaling costs one VPU
multiply per output tile.

Block defaults (512, 512, 256) target v5e VMEM: x tile 512x256xbf16 = 256KiB,
w tile 256x512x1B = 128KiB, acc 512x512xf32 = 1MiB — ~1.4MiB working set, and
(512,512,256) keeps every MXU dim a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.formats import PositFormat
from .posit_decode import decode_tile


def _matmul_kernel(x_ref, w_ref, s_ref, o_ref, *, fmt, nk, compute_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = decode_tile(w_ref[...], fmt, compute_dtype)
    x = x_ref[...].astype(compute_dtype)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _apply_scale():
        o_ref[...] *= s_ref[...]


@functools.partial(jax.jit, static_argnames=("fmt", "blocks", "compute_dtype",
                                             "interpret"))
def posit_matmul(x, w_codes, fmt: PositFormat, scale=None, *,
                 blocks=(512, 512, 256), compute_dtype=jnp.float32,
                 interpret=None):
    """x: (M, K) float; w_codes: (K, N) posit codes; scale: None | scalar |
    (N,) per-output-channel. Returns (M, N) float32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, kdim = x.shape
    k2, n = w_codes.shape
    assert kdim == k2, (x.shape, w_codes.shape)
    bm, bn, bk = (min(blocks[0], m), min(blocks[1], n), min(blocks[2], kdim))
    pm, pn, pk = -m % bm, -n % bn, -kdim % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w_codes, ((0, pk), (0, pn)))
    if scale is None:
        srow = jnp.ones((1, n), jnp.float32)
    else:
        scale = jnp.asarray(scale, jnp.float32)
        # a (N, 1) or other-shaped scale silently flattened by reshape(1, -1)
        # would mis-scale every output column; accept only a scalar or a
        # per-output-channel (N,) / (1, N) vector.
        if scale.ndim == 0 or scale.shape in ((1,), (1, 1)):
            srow = jnp.broadcast_to(scale.reshape(1, 1), (1, n))
        elif scale.shape in ((n,), (1, n)):
            srow = scale.reshape(1, n)
        else:
            raise ValueError(
                f"posit_matmul scale must be a scalar or per-output-channel "
                f"of shape ({n},) / (1, {n}); got shape {scale.shape}")
    sp = jnp.pad(srow, ((0, 0), (0, pn)))
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, fmt=fmt, nk=gk,
                          compute_dtype=compute_dtype),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=interpret,
    )(xp, wp, sp)
    return out[:m, :n]
