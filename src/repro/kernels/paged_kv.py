"""Pallas TPU kernels: vLLM-style paged posit KV cache.

The ring cache (``kernels/kv_cache.py``) reserves a dense ``max_len`` ring
per slot, so HBM scales with the worst case.  This module replaces the
per-slot ring with a shared *page pool* plus per-sequence page tables —
the paging indirection the ROADMAP names as the next step after PR 1 —
while keeping the posit code + per-row pow2 scale storage and the
decode-on-read datapath.

Layout (per attention layer; no batch axis — pages are shared):

  pool codes   (R, nkv, Dc)   R = num_pages * page_size flat rows;
                              page p owns rows [p*ps, (p+1)*ps)
  pool scales  (R, nkv) f32   per-(token x head) pow2 scale
  page_table   (B, Pmax) i32  logical page -> physical page per slot;
                              unallocated entries point at page 0, which
                              the allocator reserves as a trash page
  seq_lens     (B,) i32       valid tokens per slot (masks trash reads)

  write path  ``paged_kv_append``     — the destination flat row
      (table[b, pos//ps] * ps + pos%ps) is computed outside and handed to
      the kernel as a scalar-prefetch vector, so only the written
      (1, Dc) row blocks move between HBM and VMEM and the pool buffers
      are donated (``input_output_aliases``), exactly like the ring
      ``kv_append``.
  read path   ``paged_decode_attention`` — the grid's innermost dim walks
      the sequence's page list: the page-table row is scalar-prefetched
      and the *index map* uses it to DMA physical pages into VMEM, where
      posit tiles are decoded right before the online-softmax MACs.
      (m, l, acc) live in VMEM scratch across the page walk.

Pure-jnp references (``paged_kv_append_ref`` / ``paged_decode_attention_ref``
/ ``gather_pages``) share the codec with the kernels, so CPU serving and
the Pallas path agree bit-for-bit on pool contents; the reference read
path reuses ``attention.decode_attention``'s dense masked softmax so ring
and paged greedy decode match exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import PositFormat
from .kv_cache import (NEG_INF, decode_kv_rows, encode_kv_rows,
                       unpack_nibbles)
from .posit_decode import decode_tile


def flat_dst_rows(page_table, pos, page_size: int):
    """Per-slot flat pool row for writing the token at ``pos``.

    page_table: (B, Pmax) i32; pos: (B,) i32.  The T=1 case of
    ``flat_dst_rows_chunk`` (logical page indices clamped, so idle slots
    whose pos runs past Pmax * ps still map to trash-page rows)."""
    return flat_dst_rows_chunk(page_table, pos, 1, page_size)[:, 0]


def flat_dst_rows_chunk(page_table, pos, t: int, page_size: int):
    """(B, T) flat pool rows for a T-token chunk starting at ``pos``.

    Row [b, i] addresses the token at position pos[b] + i (speculative
    verify writes the whole chunk before scoring it).  Logical page
    indices are clamped exactly like ``flat_dst_rows``, so idle slots
    (all-trash tables) keep writing benign garbage into page 0."""
    pmax = page_table.shape[1]
    pos = (jnp.asarray(pos, jnp.int32)[:, None]
           + jnp.arange(t, dtype=jnp.int32)[None, :])        # (B, T)
    lpi = jnp.clip(pos // page_size, 0, pmax - 1)
    phys = jnp.take_along_axis(page_table, lpi, axis=1)
    return phys * page_size + pos % page_size


# ---------------------------------------------------------------------------
# paged_kv_append: encode-on-write into table-addressed pool rows (Pallas)
# ---------------------------------------------------------------------------

def paged_kv_append(k_codes, k_scale, v_codes, v_scale, k_new, v_new, dst,
                    fmt: PositFormat, *, packed: bool = False,
                    interpret=None):
    """Encode-on-write append into the paged pool.

    k/v_codes: (R, nkv, Dc) pool; k/v_scale: (R, nkv) f32; k/v_new:
    (B, 1, nkv, hd) float; dst: (B,) i32 flat pool rows (``flat_dst_rows``).
    Returns the four updated pool arrays (donated/aliased).  The T=1 case
    of ``paged_kv_append_rows`` — one kernel to maintain, identical codec
    by construction."""
    dst = jnp.asarray(dst, jnp.int32).reshape(k_new.shape[0], 1)
    return paged_kv_append_rows(k_codes, k_scale, v_codes, v_scale, k_new,
                                v_new, dst, fmt, packed=packed,
                                interpret=interpret)


def paged_kv_append_ref(k_codes, k_scale, v_codes, v_scale, k_new, v_new,
                        dst, fmt: PositFormat, packed: bool = False):
    """Pure-jnp oracle for ``paged_kv_append`` (the T=1 case of
    ``paged_kv_append_rows_ref``)."""
    dst = jnp.asarray(dst, jnp.int32).reshape(k_new.shape[0], 1)
    return paged_kv_append_rows_ref(k_codes, k_scale, v_codes, v_scale,
                                    k_new, v_new, dst, fmt, packed)


# ---------------------------------------------------------------------------
# paged_kv_append_rows: chunked encode-on-write into pool rows (Pallas)
# ---------------------------------------------------------------------------

def _paged_append_rows_kernel(dst_ref, kn_ref, vn_ref, kc_ref, ks_ref,
                              vc_ref, vs_ref, kco_ref, kso_ref, vco_ref,
                              vso_ref, *, fmt, packed):
    del dst_ref, kc_ref, ks_ref, vc_ref, vs_ref  # rows consumed by the specs
    kc, ks = encode_kv_rows(kn_ref[0, 0, 0], fmt, packed)
    vc, vs = encode_kv_rows(vn_ref[0, 0, 0], fmt, packed)
    kco_ref[0, 0] = kc
    vco_ref[0, 0] = vc
    kso_ref[0, 0] = ks[0]
    vso_ref[0, 0] = vs[0]


@functools.partial(jax.jit, static_argnames=("fmt", "packed", "interpret"))
def paged_kv_append_rows(k_codes, k_scale, v_codes, v_scale, k_new, v_new,
                         dst, fmt: PositFormat, *, packed: bool = False,
                         interpret=None):
    """Encode-on-write append of a T-token chunk into the paged pool.

    Generalizes ``paged_kv_append`` from one row to T rows per slot:
    k/v_new are (B, T, nkv, hd) floats and ``dst`` is the (B, T) flat-row
    matrix from ``flat_dst_rows_chunk``.  Live slots never share rows;
    idle slots may collide on the trash page, where the sequential grid
    makes the last write win — benign garbage either way."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, t, h, hd = k_new.shape
    dc = k_codes.shape[-1]
    dst = jnp.asarray(dst, jnp.int32).reshape(b, t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, t, h),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda i, ti, j, s: (i, ti, j, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda i, ti, j, s: (i, ti, j, 0)),
            pl.BlockSpec((1, 1, dc), lambda i, ti, j, s: (s[i, ti], j, 0)),
            pl.BlockSpec((1, 1), lambda i, ti, j, s: (s[i, ti], j)),
            pl.BlockSpec((1, 1, dc), lambda i, ti, j, s: (s[i, ti], j, 0)),
            pl.BlockSpec((1, 1), lambda i, ti, j, s: (s[i, ti], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dc), lambda i, ti, j, s: (s[i, ti], j, 0)),
            pl.BlockSpec((1, 1), lambda i, ti, j, s: (s[i, ti], j)),
            pl.BlockSpec((1, 1, dc), lambda i, ti, j, s: (s[i, ti], j, 0)),
            pl.BlockSpec((1, 1), lambda i, ti, j, s: (s[i, ti], j)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_append_rows_kernel, fmt=fmt, packed=packed),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(k_codes.shape, k_codes.dtype),
            jax.ShapeDtypeStruct(k_scale.shape, k_scale.dtype),
            jax.ShapeDtypeStruct(v_codes.shape, v_codes.dtype),
            jax.ShapeDtypeStruct(v_scale.shape, v_scale.dtype),
        ],
        # operand indices include the scalar-prefetch arg (index 0)
        input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
        interpret=interpret,
    )(dst, k_new, v_new, k_codes, k_scale, v_codes, v_scale)


def paged_kv_append_rows_ref(k_codes, k_scale, v_codes, v_scale, k_new,
                             v_new, dst, fmt: PositFormat,
                             packed: bool = False):
    """Pure-jnp oracle for ``paged_kv_append_rows`` (same codec, scatter)."""
    b, t = k_new.shape[:2]
    dst = jnp.asarray(dst, jnp.int32).reshape(b * t)

    def wr(codes, scale, new):
        c, s = encode_kv_rows(new, fmt, packed)          # (B, T, nkv, Dc)
        codes = codes.at[dst].set(
            c.reshape((b * t,) + c.shape[2:]).astype(codes.dtype))
        scale = scale.at[dst].set(s[..., 0].reshape(b * t, -1))
        return codes, scale

    kc, ks = wr(k_codes, k_scale, k_new)
    vc, vs = wr(v_codes, v_scale, v_new)
    return kc, ks, vc, vs


# ---------------------------------------------------------------------------
# paged_decode_attention: page-walking fused decode (Pallas)
# ---------------------------------------------------------------------------

def _paged_attn_kernel(tbl_ref, len_ref, q_ref, kc_ref, ks_ref, vc_ref,
                       vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                       fmt, packed, ps, npg):
    del tbl_ref  # consumed by the index maps (page DMA addressing)
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode-on-read: one physical page's posit codes -> f32 in VMEM
    kc = kc_ref[:, 0]                                          # (ps, Dc)
    vc = vc_ref[:, 0]
    k = decode_tile(unpack_nibbles(kc) if packed else kc,
                    fmt, jnp.float32) * ks_ref[:, 0][:, None]  # (ps, hd)
    v = decode_tile(unpack_nibbles(vc) if packed else vc,
                    fmt, jnp.float32) * vs_ref[:, 0][:, None]
    q = q_ref[0, 0].astype(jnp.float32)                        # (grp, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)    # (grp, ps)
    kpos = pi * ps + jnp.arange(ps)
    s = jnp.where((kpos < len_ref[bi])[None, :], s, NEG_INF)
    m_new = jnp.maximum(m_ref[...], s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pi == npg - 1)
    def _finish():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("fmt", "page_size", "packed",
                                             "interpret"))
def paged_decode_attention(q, k_codes, k_scale, v_codes, v_scale,
                           page_table, seq_lens, fmt: PositFormat, *,
                           page_size: int, packed: bool = False,
                           interpret=None):
    """Fused one-token GQA attention over a paged posit pool.

    q: (B, 1, nh, hd); k/v_codes: (R, nkv, Dc) pool; k/v_scale: (R, nkv);
    page_table: (B, Pmax) i32 (entries must be valid physical pages —
    unallocated logical pages point at the trash page and are masked by
    ``seq_lens``); seq_lens: (B,) i32.  The grid's innermost dimension
    walks the Pmax page-table entries of each (slot, kv-head) row with
    (m, l, acc) carried in VMEM scratch.  Returns (B, 1, nh, hd)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    r, nkv, dc = k_codes.shape
    b, _, nh, hd = q.shape
    grp = nh // nkv
    npg = page_table.shape[1]
    num_pages = r // page_size
    tbl = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, num_pages - 1)
    lens = jnp.broadcast_to(jnp.asarray(seq_lens, jnp.int32), (b,))
    qg = (q.reshape(b, nkv, grp, hd) * (hd ** -0.5)).astype(jnp.float32)
    ps = page_size

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nkv, npg),
        in_specs=[
            pl.BlockSpec((1, 1, grp, hd), lambda i, j, p, t, ln: (i, j, 0, 0)),
            pl.BlockSpec((ps, 1, dc), lambda i, j, p, t, ln: (t[i, p], j, 0)),
            pl.BlockSpec((ps, 1), lambda i, j, p, t, ln: (t[i, p], j)),
            pl.BlockSpec((ps, 1, dc), lambda i, j, p, t, ln: (t[i, p], j, 0)),
            pl.BlockSpec((ps, 1), lambda i, j, p, t, ln: (t[i, p], j)),
        ],
        out_specs=pl.BlockSpec((1, 1, grp, hd),
                               lambda i, j, p, t, ln: (i, j, 0, 0)),
        scratch_shapes=[pltpu.VMEM((grp, 1), jnp.float32),
                        pltpu.VMEM((grp, 1), jnp.float32),
                        pltpu.VMEM((grp, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_attn_kernel, fmt=fmt, packed=packed,
                          ps=ps, npg=npg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, grp, hd), jnp.float32),
        interpret=interpret,
    )(tbl, lens, qg, k_codes, k_scale, v_codes, v_scale)
    return out.reshape(b, 1, nh, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Pure-jnp references
# ---------------------------------------------------------------------------

def gather_pages(pool, page_table, page_size: int):
    """Gather a per-slot logical view from a flat pool.

    pool: (R, ...) flat rows; page_table: (B, Pmax).  Returns
    (B, Pmax * page_size, ...) — logical token order, trash rows included
    (callers mask by seq_lens)."""
    num_pages = pool.shape[0] // page_size
    tbl = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, num_pages - 1)
    rows = tbl[:, :, None] * page_size + jnp.arange(page_size)[None, None, :]
    b, npg = tbl.shape
    return pool[rows.reshape(b, npg * page_size)]


def gather_decode_pages(codes, scales, page_table, page_size: int,
                        fmt: PositFormat, packed: bool = False):
    """Gather a slot-logical view of a posit pool and decode it to floats:
    (R, nkv, Dc) codes + (R, nkv) scales -> (B, Pmax*ps, nkv, hd).  The
    single codec path shared by the reference attention and the serving
    fallbacks, so ring/paged equivalence has one implementation to pin."""
    return decode_kv_rows(
        gather_pages(codes, page_table, page_size),
        gather_pages(scales, page_table, page_size)[..., None], fmt, packed)


def paged_decode_attention_ref(q, k_codes, k_scale, v_codes, v_scale,
                               page_table, seq_lens, fmt: PositFormat, *,
                               page_size: int, packed: bool = False):
    """Pure-jnp oracle: gather the page list, decode, dense masked softmax
    (via ``attention.decode_attention`` so ring/paged refs share the exact
    reduction order)."""
    from ..models.attention import decode_attention
    k = gather_decode_pages(k_codes, k_scale, page_table, page_size, fmt,
                            packed)
    v = gather_decode_pages(v_codes, v_scale, page_table, page_size, fmt,
                            packed)
    return decode_attention(q, k, v, seq_lens)
