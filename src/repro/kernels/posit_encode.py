"""Pallas TPU kernel: float -> posit encode (quantize-on-store).

Bit-exact RNE assembly (guard/sticky on the regime/exponent/fraction
concatenation), saturating to maxpos/minpos.  Used for KV-cache / gradient
wire quantization where the store side is the bandwidth bottleneck.

float32 subnormal inputs (|x| < 2^-126) are flushed to zero inside the
kernel: every assigned posit format maps them to minpos/zero anyway and this
keeps the body free of clz (VPU compare/shift/add only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.formats import PositFormat
from ..core.posit import mask_u32, negate_code_u32, shl_u32, shr_u32

U32 = jnp.uint32


def encode_tile(x, fmt: PositFormat):
    """Encode a float32 tile to posit codes. Pallas-safe; bit-exact RNE for
    normal floats (subnormals flushed — see module docstring)."""
    n, es = fmt.bits, fmt.es
    xf = x.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(xf, jnp.int32).astype(U32)
    s = shr_u32(bits, 31)
    exp_raw = (shr_u32(bits, 23) & mask_u32(8)).astype(jnp.int32)
    frac = bits & mask_u32(23)
    is_zero = (bits & mask_u32(31)) == 0
    is_zero = is_zero | (exp_raw == 0)  # flush subnormals
    is_nar = exp_raw == 255
    t = exp_raw - 127 - fmt.bias
    fw = 23
    # --- regime/exponent split ---
    k = t >> es
    e_field = (t - (k << es)).astype(U32)
    sat_hi = k >= n - 2
    sat_lo = k <= -(n - 1)
    k_c = jnp.clip(k, -(n - 2), n - 3)
    pos = k_c >= 0
    w0 = jnp.where(pos, k_c + 2, 1 - k_c)
    reg = jnp.where(pos, shl_u32(mask_u32((k_c + 1).astype(U32)), 1), U32(1))
    avail = jnp.int32(n - 1) - w0
    ef_shift = avail + 1 - es
    # --- case ef_shift >= 0 ---
    efp = jnp.maximum(ef_shift, 0).astype(U32)
    take = jnp.minimum(efp, U32(fw))
    fbits = shl_u32(shr_u32(frac, U32(fw) - take), efp - take)
    st_a = (frac & mask_u32(U32(fw) - take)) != 0
    efg_a = shl_u32(e_field, efp) | fbits
    # --- case ef_shift < 0 ---
    cut = jnp.maximum(-ef_shift, 0).astype(U32)
    efg_b = shr_u32(e_field, cut)
    st_b = ((e_field & mask_u32(cut)) != 0) | (frac != 0)
    neg_case = ef_shift < 0
    efg = jnp.where(neg_case, efg_b, efg_a)
    st = jnp.where(neg_case, st_b, st_a)
    guard = efg & U32(1)
    kept = shr_u32(efg, 1)
    body = shl_u32(reg, avail.astype(U32)) | kept
    body = body + (guard & (st.astype(U32) | (body & U32(1))))
    body = jnp.where(sat_hi, mask_u32(n - 1), body)
    body = jnp.where(sat_lo, U32(1), body)
    body = jnp.clip(body, U32(1), mask_u32(n - 1))
    code = jnp.where(s == 1, negate_code_u32(body, n), body)
    code = jnp.where(is_zero, U32(0), code)
    code = jnp.where(is_nar, U32(1) << U32(n - 1), code)
    return code.astype(fmt.storage_dtype)


def _encode_kernel(x_ref, o_ref, *, fmt):
    o_ref[...] = encode_tile(x_ref[...], fmt)


@functools.partial(jax.jit, static_argnames=("fmt", "block", "interpret"))
def posit_encode(x, fmt: PositFormat, *, block=(256, 256), interpret=None):
    """Blocked posit encode. x: (M, N) float -> (M, N) posit codes."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    pm, pn = -m % bm, -n % bn
    padded = jnp.pad(x, ((0, pm), (0, pn)))
    out = pl.pallas_call(
        functools.partial(_encode_kernel, fmt=fmt),
        grid=(padded.shape[0] // bm, padded.shape[1] // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, fmt.storage_dtype),
        interpret=interpret,
    )(padded)
    return out[:m, :n]
