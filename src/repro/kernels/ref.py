"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

from ..core import posit
from ..core.formats import PositFormat


def posit_decode_ref(codes, fmt: PositFormat, out_dtype=jnp.float32):
    """Oracle for kernels.posit_decode: bit-exact posit -> float."""
    return posit.decode_to_f32(codes, fmt).astype(out_dtype)


def posit_encode_ref(x, fmt: PositFormat):
    """Oracle for kernels.posit_encode: bit-exact RNE float -> posit."""
    return posit.encode_f32(x, fmt)


def posit_matmul_ref(x, w_codes, fmt: PositFormat, scale=None,
                     out_dtype=jnp.float32):
    """Oracle for kernels.posit_matmul: decode weights, f32 matmul, scale."""
    w = posit.decode_to_f32(w_codes, fmt)
    out = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    if scale is not None:
        out = out * scale
    return out.astype(out_dtype)
