"""Pallas TPU kernel: posit -> float decode (Algorithm 1 on the VPU).

The kernel body is the paper's decode, vectorized: the regime is found with
n-1 *parallel threshold comparisons* (the thermometer/Q-function form —
deliberately not clz, so every op is a plain VPU compare/add and the kernel
mirrors the TALU datapath), then exponent/fraction are exposed with shifts
and the IEEE-754 bit pattern is assembled integer-only (no transcendentals).

Tiles are (block_m, block_n) in VMEM; codes are uint8/uint16, output f32 or
bf16.  Arithmetic intensity is trivial (this kernel exists to *fuse* into
consumers — see posit_matmul which inlines `decode_tile`), but a standalone
decode is useful for cache/state dequantization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.formats import PositFormat
from ..core.posit import mask_u32, negate_code_u32, shl_u32, shr_u32

U32 = jnp.uint32


def decode_tile(codes, fmt: PositFormat, out_dtype=jnp.float32):
    """Decode a tile of posit codes to float. Pure jnp; Pallas-safe ops only
    (compares, shifts, adds — no clz, no gather). Bit-exact for n<=16."""
    n, es = fmt.bits, fmt.es
    u = codes.astype(U32) & mask_u32(n)
    is_zero = u == 0
    is_nar = u == (U32(1) << U32(n - 1))
    s = shr_u32(u, n - 1) & U32(1)
    mag = jnp.where(s == 1, negate_code_u32(u, n), u)
    body = mag & mask_u32(n - 1)
    lead = shr_u32(body, n - 2) & U32(1)
    t_val = jnp.where(lead == 1, body, (~body) & mask_u32(n - 1))
    # --- Algorithm 1: parallel threshold comparisons (unrolled, VPU) ---
    r = jnp.zeros_like(u)
    for i in range(n - 1):
        thr = U32((1 << (n - 1)) - (1 << i))  # 2^{n-1}-1-(2^i-1)
        r = r + (t_val >= thr).astype(U32)
    k = jnp.where(lead == 1, r.astype(jnp.int32) - 1, -r.astype(jnp.int32))
    rem_i = jnp.maximum(jnp.int32(n - 1) - r.astype(jnp.int32) - 1, 0)
    rem = rem_i.astype(U32)
    rest = body & mask_u32(rem)
    e_have = jnp.minimum(rem, U32(es))
    e_field = shl_u32(shr_u32(rest, rem - e_have), U32(es) - e_have)
    f_len = jnp.maximum(rem_i - es, 0).astype(U32)
    f_field = rest & mask_u32(f_len)
    t = (k << es) + e_field.astype(jnp.int32) + fmt.bias
    # --- IEEE-754 assembly (f_len <= 13 <= 23: exact) ---
    man = shl_u32(f_field, U32(23) - f_len)
    bits = shl_u32(s, 31) | shl_u32((t + 127).astype(U32), 23) | man
    val = jax.lax.bitcast_convert_type(bits, jnp.float32)
    val = jnp.where(is_zero, 0.0, val)
    val = jnp.where(is_nar, jnp.nan, val)
    return val.astype(out_dtype)


def _decode_kernel(c_ref, o_ref, *, fmt, out_dtype):
    o_ref[...] = decode_tile(c_ref[...], fmt, out_dtype)


@functools.partial(jax.jit, static_argnames=("fmt", "block", "out_dtype", "interpret"))
def posit_decode(codes, fmt: PositFormat, *, block=(256, 256),
                 out_dtype=jnp.float32, interpret=None):
    """Blocked posit decode. codes: (M, N) uint8/uint16 -> (M, N) float."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, n = codes.shape
    bm, bn = min(block[0], m), min(block[1], n)
    pm, pn = -m % bm, -n % bn
    padded = jnp.pad(codes, ((0, pm), (0, pn)))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, fmt=fmt, out_dtype=out_dtype),
        grid=(padded.shape[0] // bm, padded.shape[1] // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, out_dtype),
        interpret=interpret,
    )(padded)
    return out[:m, :n]
