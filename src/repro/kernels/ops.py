"""Public jit'd entry points for the Pallas kernels.

These wrap the raw ``pallas_call`` kernels with QuantizedTensor plumbing so
model code can stay format-agnostic.  On CPU (this container) the kernels
run in interpret mode; on TPU they compile to Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..core.formats import PositFormat, get
from ..core.quant import QuantizedTensor
from .posit_decode import posit_decode
from .posit_encode import posit_encode
from .posit_matmul import posit_matmul

__all__ = ["posit_decode", "posit_encode", "posit_matmul", "qt_matmul",
           "qt_decode", "quantize_2d"]


def qt_matmul(x, w: QuantizedTensor, **kw):
    """x @ dequant(w) with in-VMEM decode (w stored as packed posit)."""
    assert isinstance(w.fmt, PositFormat), "qt_matmul expects posit storage"
    return posit_matmul(x, w.data, w.fmt, scale=w.scale, **kw)


def qt_decode(w: QuantizedTensor, out_dtype=jnp.float32, **kw):
    assert isinstance(w.fmt, PositFormat)
    out = posit_decode(w.data, w.fmt, out_dtype=out_dtype, **kw)
    if w.scale is not None:
        out = out * w.scale
    return out


def quantize_2d(x, fmt_name: str, **kw) -> QuantizedTensor:
    """Kernel-path 2-D quantize (unscaled posit storage)."""
    fmt = get(fmt_name)
    assert isinstance(fmt, PositFormat)
    return QuantizedTensor(posit_encode(x, fmt, **kw), None, fmt)
