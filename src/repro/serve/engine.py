"""Batched serving engine: continuous batching over a TALU-style
transprecision model (posit-packed weights decoded on load).

Slot-based continuous batching: a fixed batch of B slots; finished
sequences free their slot and the next queued request is prefilled into it
while other slots keep decoding — the standard production pattern
(vLLM-style) reduced to its JAX-native core:

* ``decode_step`` is ONE jitted program for the whole batch, with TRUE
  per-slot positions (``cache["pos"]`` is a (B,) vector): heterogeneous
  prompt lengths batch correctly — each slot ropes, writes and masks at
  its own position, so greedy outputs match single-sequence decode
  exactly;
* prefill for a joining request runs as a separate jitted call whose
  K/V rows are merged into the live batch cache with donated
  ``dynamic_update_slice`` / page-pool scatters on only the leaves that
  carry per-slot state (no full-cache copy per admission);
* two KV layouts (``kv_layout``): ``ring`` reserves a dense max_len ring
  per slot; ``paged`` runs a shared posit page pool + per-sequence page
  tables (``serve/paged.py`` allocator, ``kernels/paged_kv.py`` device
  path) so HBM tracks live tokens and freed sequences return their pages
  immediately.  Admission control reserves each request's worst-case
  page demand (prompt + max_new) in accounting while allocating pages on
  demand, so mid-decode growth never exhausts the pool;
* admission scans the whole queue for the first admissible request, so
  one oversized/unplaceable head never starves slots later entries could
  fill (no head-of-line blocking);
* sampling: greedy or temperature (per-request).

For single-host examples this runs real tokens end-to-end; the multi-pod
decode path (KV-sharded + LSE combine) is exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.transprecision import BF16, TCPolicy, get_policy
from ..models import lm
from ..models.serve_model import decode_step, init_cache, prefill
from .paged import PageAllocator, SlotPages, pages_for

_KV_LEAF_NAMES = ("k", "v", "k_scale", "v_scale", "xk", "xv")
_POOL_LEAF_NAMES = ("k", "v", "k_scale", "v_scale")


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0     # 0 => greedy
    seed: int = 0
    eos_id: Optional[int] = None
    # KV-cache storage override (f32|bf16|posit16|posit8|posit4); None
    # keeps the policy's own kv_format / legacy packed_kv resolution.
    kv_format: Optional[str] = None
    # KV-cache layout override (ring|paged); None keeps the policy's.
    kv_layout: Optional[str] = None
    # paged layout: tokens per page (None keeps the policy's) and total
    # physical pages incl. the trash page (None = full reservation:
    # 1 + max_batch * ceil(max_len / page_size)).  Undersizing the pool
    # is how paging saves HBM: pages are *allocated* on demand as
    # sequences grow, but admission *reserves* each request's worst case
    # (prompt + max_new) in accounting, so decode-time growth can never
    # exhaust the pool — requests queue until reservations free up.
    page_size: Optional[int] = None
    num_pages: Optional[int] = None


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    # per-request sampling temperature; None inherits ServeConfig's.
    # 0 (or an inherited 0) means greedy — the speculative path keys its
    # greedy-only admission check off this same resolved value.
    temperature: Optional[float] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None  # set when the request is rejected


def _slot_update(dst, src, slot):
    """Write the single-row ``src`` into ``dst`` at batch index ``slot``.
    The batch axis is the first axis where the sizes differ; identical
    shapes mean max_batch == 1 (take src)."""
    if dst.shape == src.shape:
        return src.astype(dst.dtype)
    ax = next(i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
              if a != b)
    return jax.lax.dynamic_update_slice_in_dim(
        dst, src.astype(dst.dtype), slot, axis=ax)


class ServingEngine:
    def __init__(self, cfg: lm.ModelCfg, params, scfg: ServeConfig,
                 policy: TCPolicy = BF16):
        self.cfg = cfg
        self.scfg = scfg
        self.policy = get_policy(policy)
        overrides = {}
        if scfg.kv_format is not None:
            overrides["kv_format"] = scfg.kv_format
        if scfg.kv_layout is not None:
            overrides["kv_layout"] = scfg.kv_layout
        if scfg.page_size is not None:
            overrides["kv_page_size"] = scfg.page_size
        if overrides:
            tag = "+".join(f"{k[3:]}_{v}" for k, v in overrides.items())
            self.policy = dataclasses.replace(
                self.policy, name=f"{self.policy.name}+{tag}", **overrides)
        self.params = params
        b, L = scfg.max_batch, scfg.max_len
        self.paged = self.policy.kv_layout == "paged"

        if self.paged:
            ps = self.policy.kv_page_size
            self._pmax = pages_for(L, ps)
            self.num_pages = (scfg.num_pages if scfg.num_pages is not None
                              else 1 + b * self._pmax)
            self.allocator = PageAllocator(self.num_pages, ps)
            self.slot_pages = [SlotPages(ps) for _ in range(b)]
            # worst-case page reservations (admission control): pages a
            # slot may still grow into are committed but not yet allocated
            self._committed = 0
            self._slot_commit = [0] * b
            self._table = np.zeros((b, self._pmax), np.int32)
            self.cache = init_cache(cfg, b, L, policy=self.policy,
                                    num_pages=self.num_pages)
            self.cache["page_table"] = jnp.asarray(self._table)
            # prompts prefill through the ring datapath (identical codec)
            # and their rows are scattered into pool pages at admission
            self._prefill_policy = dataclasses.replace(
                self.policy, kv_layout="ring",
                name=self.policy.name + "+prefill_ring")
        else:
            self.allocator = None
            self.cache = init_cache(cfg, b, L, policy=self.policy)
            self._prefill_policy = self.policy
        # true per-slot positions (both layouts)
        self.cache["pos"] = jnp.zeros((b,), jnp.int32)
        self.slot_pos = np.zeros(b, np.int64)         # valid tokens per slot
        self.slot_req: List[Optional[Request]] = [None] * b
        self.last_tok = np.zeros((b, 1), np.int32)

        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, cfg, self.policy))
        self._prefill = jax.jit(
            lambda p, batch: prefill(p, batch, cfg, L, self._prefill_policy))
        # donation keeps admission from copying the whole batch cache
        # (ignored with a warning on CPU, so only request it off-CPU)
        donate = () if jax.default_backend() == "cpu" else (0,)
        self._merge = jax.jit(self._merge_prefill, donate_argnums=donate)
        self._rng = np.random.default_rng(scfg.seed)
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0,
                      "rejected": 0, "peak_live_pages": 0,
                      "kv_cache_bytes": self.kv_cache_bytes()}

    # ---- cache footprint ----
    def _kv_bytes(self, pool_frac: float = 1.0, cache=None) -> int:
        """Sum KV-cache leaf bytes across any cache layout by leaf name
        (``k``/``v``/scales/cross-K/V at any depth — no layout-specific
        key assumptions).  ``pool_frac`` scales page-pool leaves (paged
        layout) by an allocated-page fraction; cross-K/V does not page.
        ``cache`` defaults to the engine's target cache (the speculative
        engine also passes its ring-layout draft cache, where
        ``pool_frac`` must stay 1.0)."""

        total = 0.0
        paged = self.paged and cache is None

        def visit(kp, leaf):
            nonlocal total
            name = str(getattr(kp[-1], "key", getattr(kp[-1], "idx", kp[-1])))
            if name not in _KV_LEAF_NAMES or not hasattr(leaf, "dtype"):
                return
            nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            if paged and name in _POOL_LEAF_NAMES:
                nbytes *= pool_frac
            total += nbytes

        jax.tree_util.tree_map_with_path(
            visit, dict(self.cache if cache is None else cache))
        return int(total)

    def kv_cache_bytes(self) -> int:
        """Reserved HBM footprint of the attention K/V state (codes +
        scales + cross-K/V), for every layout."""
        return self._kv_bytes()

    def kv_cache_live_bytes(self) -> int:
        """Footprint counting only allocated pages for the paged layout
        (== reserved for ring, which preallocates everything)."""
        if not self.paged:
            return self._kv_bytes()
        return self._kv_bytes(self.allocator.live_pages / self.num_pages)

    def kv_cache_peak_live_bytes(self) -> int:
        """High-water live-page footprint over the served run (== reserved
        for ring)."""
        if not self.paged:
            return self._kv_bytes()
        return self._kv_bytes(self.stats["peak_live_pages"] / self.num_pages)

    # ---- slot management ----
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _merge_prefill(self, cache, cache1, slot, dst_rows):
        """Merge a single-row prefill cache into the batch cache at
        ``slot`` — donated, touching only the per-slot leaves.

        Ring K/V (and recurrent/SSM/cross state) rows land via
        ``dynamic_update_slice``; with the paged layout the prompt's K/V
        rows are scattered into the slot's pool pages at the
        ``dst_rows`` flat rows instead (codes are codec-identical between
        the ring prefill and the pool, so this is a pure relayout).
        ``dst_rows is None`` selects the ring semantics even on a paged
        engine — the speculative draft cache is always a ring."""
        s_len = dst_rows.shape[0] if dst_rows is not None else 0

        def merge_block(dstb, srcb, stacked):
            out = {}
            for name, d in dstb.items():
                s = srcb[name]
                if dst_rows is not None and name in _POOL_LEAF_NAMES:
                    if stacked:            # (P, R, ...) <- (P, 1, W, ...)
                        rows = s[:, 0, :s_len]
                        out[name] = d.at[:, dst_rows].set(rows.astype(d.dtype))
                    else:                  # (R, ...) <- (1, W, ...)
                        out[name] = d.at[dst_rows].set(
                            s[0, :s_len].astype(d.dtype))
                else:
                    out[name] = _slot_update(d, s, slot)
            return out

        new_cache = dict(cache)
        new_cache["pos"] = cache["pos"].at[slot].set(
            jnp.max(cache1["pos"]).astype(cache["pos"].dtype))
        new_cache["blocks"] = tuple(
            merge_block(d, s, True)
            for d, s in zip(cache["blocks"], cache1["blocks"]))
        if "tail" in cache:
            new_cache["tail"] = tuple(
                merge_block(d, s, False)
                for d, s in zip(cache["tail"], cache1["tail"]))
        # any other top-level per-slot state (e.g. audio "memory", future
        # family additions) merges generically; page_table is engine-owned
        # and absent from the ring prefill cache
        for name, d in cache.items():
            if name in ("pos", "blocks", "tail", "page_table"):
                continue
            if name in cache1:
                new_cache[name] = _slot_update(d, cache1[name], slot)
        return new_cache

    def add_request(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False if no slot (or, paged,
        not enough free pages) — the request stays queued.  Prompts that
        can never fit (``serve`` rejects these up front) are a caller
        error here: raising beats silently corrupting the page
        accounting."""
        s_len = len(req.prompt)
        if s_len >= self.scfg.max_len:
            raise ValueError(f"prompt length {s_len} >= max_len "
                             f"{self.scfg.max_len}; reject before admission")
        slot = self._free_slot()
        if slot is None:
            return False
        dst_rows = None
        if self.paged:
            ps = self.allocator.page_size
            # admission control reserves the worst case this request can
            # grow to; allocation itself stays on-demand (live bytes track
            # actual tokens), and the reservation invariant guarantees the
            # growth allocs in step() can never fail
            worst = self._worst_pages(req)
            if self._committed + worst > self.num_pages - 1:
                return False
            pages = self.allocator.alloc(pages_for(s_len + 1, ps))
            if pages is None:       # unreachable under the invariant
                return False
            self._committed += worst
            self._slot_commit[slot] = worst
            self.slot_pages[slot] = sp = SlotPages(ps, pages)
            self._table[slot] = sp.table_row(self._pmax)
            self.cache["page_table"] = jnp.asarray(self._table)
            t = np.arange(s_len)
            dst_rows = jnp.asarray(
                np.asarray(pages, np.int64)[t // ps] * ps + t % ps, jnp.int32)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = self._prefill(self.params, {"tokens": prompt})
        self.cache = self._merge(self.cache, cache1,
                                 jnp.asarray(slot, jnp.int32), dst_rows)
        self.slot_req[slot] = req
        self.slot_pos[slot] = s_len
        self.last_tok[slot, 0] = int(self._sample(
            np.asarray(logits), [self._req_temp(req)])[0])
        req.out_tokens.append(int(self.last_tok[slot, 0]))
        self.stats["prefills"] += 1
        self.stats["tokens"] += 1
        # prompt-only requests (max_new <= 1, or immediate EOS) finish at
        # admission — no decode tick, slot and pages free right away
        if (len(req.out_tokens) >= req.max_new
                or req.out_tokens[-1] == self.scfg.eos_id):
            req.done = True
            self._free_request_slot(slot)
        return True

    def _worst_pages(self, req: Request) -> int:
        """Worst-case page demand of ``req``: prompt + max_new tokens,
        capped by max_len (the engine stops a slot before max_len) and
        floored at prompt + 1 — admission always allocates a page for the
        first decode append, so the reservation must cover it even when
        max_new is 0."""
        s = len(req.prompt)
        tokens = min(max(s + req.max_new, s + 1), self.scfg.max_len)
        return pages_for(tokens, self.allocator.page_size)

    def _free_request_slot(self, slot: int) -> None:
        """Release a finished request's slot (paged: return its pages to
        the allocator immediately and point the slot at the trash page)."""
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0
        if self.paged:
            self._committed -= self._slot_commit[slot]
            self._slot_commit[slot] = 0
            self.allocator.free(self.slot_pages[slot].pages)
            self.slot_pages[slot] = SlotPages(self.allocator.page_size)
            self._table[slot] = 0
            self.cache["page_table"] = jnp.asarray(self._table)
            # park the idle slot's write position on the trash page
            self.cache["pos"] = self.cache["pos"].at[slot].set(0)

    def _req_temp(self, req: Request) -> float:
        """Resolved sampling temperature for ``req`` (per-request override
        falls back to the engine-wide default)."""
        return (self.scfg.temperature if req.temperature is None
                else req.temperature)

    def _sample(self, logits: np.ndarray,
                temps: Optional[np.ndarray] = None) -> np.ndarray:
        """Sample next tokens row-wise.  ``temps`` is a per-row temperature
        vector (None = the engine-wide default for every row); rows at
        temperature <= 0 are greedy, the rest are softmax samples at their
        own temperature."""
        logits = logits[..., : self.cfg.vocab]
        greedy = logits.argmax(-1)
        if temps is None:
            temps = np.full(greedy.shape, self.scfg.temperature)
        temps = np.broadcast_to(np.asarray(temps, np.float32), greedy.shape)
        hot = temps > 0
        if not hot.any():
            return greedy
        t = np.where(hot, temps, 1.0)[..., None]
        p = jax.nn.softmax(jnp.asarray(logits) / t, -1)
        c = np.cumsum(np.asarray(p), -1)
        u = self._rng.random(c.shape[:-1] + (1,))
        sampled = (c < u).sum(-1)
        return np.where(hot, sampled, greedy)

    # ---- one decode tick for the whole batch ----
    def step(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        if self.paged:
            # grow page lists so every active slot has a page for the
            # token this tick writes at its own position
            grew = False
            for i in active:
                need = self.slot_pages[i].pages_needed(self.slot_pos[i] + 1)
                if need:
                    pages = self.allocator.alloc(need)
                    if pages is None:
                        # the admission reservation makes this unreachable
                        raise RuntimeError(
                            "paged KV pool exhausted mid-decode — the "
                            "admission reservation invariant was violated "
                            "(pages allocated outside the engine?)")
                    self.slot_pages[i].pages.extend(pages)
                    self._table[i] = self.slot_pages[i].table_row(self._pmax)
                    grew = True
            if grew:
                self.cache["page_table"] = jnp.asarray(self._table)
            self.stats["peak_live_pages"] = max(
                self.stats["peak_live_pages"], self.allocator.live_pages)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(self.last_tok))
        temps = np.asarray([0.0 if r is None else self._req_temp(r)
                            for r in self.slot_req], np.float32)
        toks = self._sample(np.asarray(logits), temps)
        self.stats["decode_steps"] += 1
        for i in active:
            req = self.slot_req[i]
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self.last_tok[i, 0] = tok
            self.slot_pos[i] += 1
            self.stats["tokens"] += 1
            eos = self.scfg.eos_id
            if (len(req.out_tokens) >= req.max_new
                    or (eos is not None and tok == eos)
                    or self.slot_pos[i] >= self.scfg.max_len - 1):
                req.done = True
                self._free_request_slot(i)

    def _reject_reason(self, req: Request) -> Optional[str]:
        """Why ``req`` can NEVER be admitted (None = admissible once a
        slot/pages free up).  Subclasses add checks (the speculative
        engine needs chunk headroom and greedy sampling)."""
        if len(req.prompt) >= self.scfg.max_len:
            return (f"prompt length {len(req.prompt)} >= "
                    f"max_len {self.scfg.max_len}")
        if self.paged and self._worst_pages(req) > self.num_pages - 1:
            return ("request worst case needs more pages than the "
                    f"pool holds ({self.num_pages - 1} allocatable)")
        return None

    def _admit(self, queue: List[Request]) -> None:
        """Admit every currently admissible queued request, scanning past
        blocked entries (no head-of-line blocking: an oversized or
        page-starved head must not starve slots later entries can fill).
        FIFO priority is kept — earlier entries get first pick."""
        i = 0
        while i < len(queue):
            req = queue[i]
            reject = self._reject_reason(req)
            if reject is not None:
                req.done = True
                req.error = reject
                self.stats["rejected"] += 1
                queue.pop(i)
                continue
            if self.add_request(req):
                queue.pop(i)
                continue
            i += 1

    def serve(self, requests: List[Request], max_ticks: int = 10_000
              ) -> Dict[str, Any]:
        """Run to completion with continuous batching."""
        queue = list(requests)
        t0 = time.time()
        ticks = 0
        while (queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self._admit(queue)
            self.step()
            ticks += 1
        dt = time.time() - t0
        # live bytes at drain are ~0 by construction (every finished
        # request returns its pages); the peak is the meaningful figure
        return {"wall_s": dt, **self.stats,
                "kv_peak_live_bytes": self.kv_cache_peak_live_bytes(),
                "tok_per_s": self.stats["tokens"] / max(dt, 1e-9)}
