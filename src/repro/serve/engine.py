"""Batched serving engine: continuous batching over a TALU-style
transprecision model (posit-packed weights decoded on load).

This is the synchronous host-side *driver* over the disaggregated
three-stage engine API (``serve/engine_api.py``):

    prefill(params, tokens, lengths) -> Prefix
    insert(prefix, decode_state, slot) -> decode_state
    generate(params, decode_state)    -> (decode_state, logits)

Slot-based continuous batching: a fixed batch of B slots; finished
sequences free their slot and the next queued request is prefilled into it
while other slots keep decoding — the standard production pattern
(vLLM-style) reduced to its JAX-native core:

* ``generate`` is ONE jitted program for the whole batch, with TRUE
  per-slot positions (``cache["pos"]`` is a (B,) vector): heterogeneous
  prompt lengths batch correctly — each slot ropes, writes and masks at
  its own position, so greedy outputs match single-sequence decode
  exactly;
* prompts prefill in power-of-two *buckets* (right-padded, per-row true
  lengths — padding contributes exact zeros, so outputs are bit-identical
  to unpadded prefill) and ``add_requests`` admits several queued prompts
  through one prefill call; ``insert`` merges only the per-slot leaves
  (donated — no full-cache copy per admission);
* two KV layouts (``kv_layout``): ``ring`` reserves a dense max_len ring
  per slot; ``paged`` runs a shared posit page pool + per-sequence page
  tables (``serve/paged.py`` allocator, ``kernels/paged_kv.py`` device
  path), with prefill K/V rows scattered straight into pool pages.
  Admission control reserves each request's worst-case page demand
  (prompt + max_new), so mid-decode growth never exhausts the pool;
  with ``page_overcommit`` the reservation is waived and a dry pool
  instead *evicts* the newest sequence (recompute-on-readmit,
  ``stats["evictions"]``) — higher occupancy at the cost of recompute;
* admission scans the whole queue for the first admissible request, so
  one oversized/unplaceable head never starves slots later entries could
  fill (no head-of-line blocking);
* sampling: greedy or temperature (per-request); ``on_emit`` streams
  tokens to a host-side consumer (the async ``serve/orchestrator.py``)
  as they are produced.

For single-host examples this runs real tokens end-to-end; the multi-pod
decode path (KV-sharded + LSE combine) plugs in through the engine API's
``attn_impl`` hook (``serve/distributed.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.transprecision import BF16, TCPolicy, get_policy
from ..models import lm
from ..obs import MetricsRegistry, StatsView, Tracer
from .engine_api import TransprecisionEngine
from .faults import FaultInjector, FaultPlan, RetryPolicy
from .guard import GuardConfig, NumericGuard
from .paged import PageAllocator, SlotPages, pages_for

_KV_LEAF_NAMES = ("k", "v", "k_scale", "v_scale", "xk", "xv")
_POOL_LEAF_NAMES = ("k", "v", "k_scale", "v_scale")


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0     # 0 => greedy
    seed: int = 0
    eos_id: Optional[int] = None
    # KV-cache storage override (f32|bf16|posit16|posit8|posit4); None
    # keeps the policy's own kv_format / legacy packed_kv resolution.
    kv_format: Optional[str] = None
    # KV-cache layout override (ring|paged); None keeps the policy's.
    kv_layout: Optional[str] = None
    # paged layout: tokens per page (None keeps the policy's) and total
    # physical pages incl. the trash page (None = full reservation:
    # 1 + max_batch * ceil(max_len / page_size)).  Undersizing the pool
    # is how paging saves HBM: pages are *allocated* on demand as
    # sequences grow, but admission *reserves* each request's worst case
    # (prompt + max_new) in accounting, so decode-time growth can never
    # exhaust the pool — requests queue until reservations free up.
    page_size: Optional[int] = None
    num_pages: Optional[int] = None
    # waive the worst-case reservation and admit on current demand only;
    # if the pool then runs dry mid-decode the newest-admitted sequence
    # is evicted and requeued for recompute-on-readmit
    # (stats["evictions"]) instead of raising.
    page_overcommit: bool = False


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    # per-request sampling temperature; None inherits ServeConfig's.
    # 0 (or an inherited 0) means greedy — the speculative path keys its
    # greedy-only admission check off this same resolved value.
    temperature: Optional[float] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None  # set when the request is rejected
    # lifecycle stamps (``time.perf_counter()``): submit, admit,
    # prefill_done, insert_done, first_token, finish.  Stamped with
    # ``setdefault`` so readmission after a page-pool eviction keeps the
    # request's ORIGINAL stamps — TTFT means first token ever streamed.
    timing: Dict[str, float] = dataclasses.field(
        default_factory=dict, repr=False)
    # recompute-on-readmit state for a page-pool eviction: the token
    # sequence (prompt + all-but-last emitted) the readmission prefills
    _resume: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)


class ServingEngine:
    def __init__(self, cfg: lm.ModelCfg, params, scfg: ServeConfig,
                 policy: TCPolicy = BF16, *, attn_impl=None,
                 tracer: Optional[Tracer] = None,
                 faults=None, retry: Optional[RetryPolicy] = None,
                 guard=None):
        self.cfg = cfg
        self.scfg = scfg
        self.policy = get_policy(policy)
        # observability: one registry per engine (the orchestrator and
        # the speculative draft engine share it); tracing defaults OFF —
        # span call sites stay in place at ~no cost (tests/test_obs.py
        # bounds the disabled overhead)
        self.tracer = tracer if tracer is not None else Tracer()
        self.metrics = MetricsRegistry()
        # chaos hardening (serve/faults.py, serve/guard.py) — all off by
        # default, leaving single `is not None` checks on the hot path:
        #   faults: a FaultPlan or FaultInjector of scheduled failures;
        #   retry:  bounded-backoff retry of transient stage failures;
        #   guard:  True or a GuardConfig arms the numeric quarantine +
        #           precision-fallback re-decode for non-finite logits.
        if faults is not None and isinstance(faults, FaultPlan):
            faults = FaultInjector(faults, metrics=self.metrics)
        self.faults: Optional[FaultInjector] = faults
        if self.faults is not None and self.faults.metrics is None:
            self.faults.metrics = self.metrics
        self.retry = retry
        self._guard_cfg = (guard if isinstance(guard, GuardConfig)
                           else (GuardConfig() if guard else None))
        overrides = {}
        if scfg.kv_format is not None:
            overrides["kv_format"] = scfg.kv_format
        if scfg.kv_layout is not None:
            overrides["kv_layout"] = scfg.kv_layout
        if scfg.page_size is not None:
            overrides["kv_page_size"] = scfg.page_size
        if overrides:
            tag = "+".join(f"{k[3:]}_{v}" for k, v in overrides.items())
            self.policy = dataclasses.replace(
                self.policy, name=f"{self.policy.name}+{tag}", **overrides)
        self.params = params
        b, L = scfg.max_batch, scfg.max_len
        self.paged = self.policy.kv_layout == "paged"

        if self.paged:
            ps = self.policy.kv_page_size
            self._pmax = pages_for(L, ps)
            self.num_pages = (scfg.num_pages if scfg.num_pages is not None
                              else 1 + b * self._pmax)
            self.allocator = PageAllocator(self.num_pages, ps,
                                           metrics=self.metrics,
                                           tracer=self.tracer,
                                           faults=self.faults)
            self.slot_pages = [SlotPages(ps) for _ in range(b)]
            # worst-case page reservations (admission control): pages a
            # slot may still grow into are committed but not yet allocated
            self._committed = 0
            self._slot_commit = [0] * b
            self._table = np.zeros((b, self._pmax), np.int32)
        else:
            self.allocator = None

        self.engine = TransprecisionEngine(
            cfg, self.policy, b, L,
            num_pages=self.num_pages if self.paged else None,
            attn_impl=attn_impl, tracer=self.tracer, metrics=self.metrics,
            faults=self.faults, retry=self.retry,
            # the guard's fallback re-decode re-reads the pre-generate
            # state, so a guarded engine must not donate it away
            donate=False if self._guard_cfg is not None else None)
        self.guard: Optional[NumericGuard] = (
            NumericGuard(self, self._guard_cfg)
            if self._guard_cfg is not None else None)
        self.cache = self.engine.init_decode_state()
        if self.paged:
            self.cache["page_table"] = jnp.asarray(self._table)
        self.slot_pos = np.zeros(b, np.int64)         # valid tokens per slot
        self.slot_req: List[Optional[Request]] = [None] * b
        self.last_tok = np.zeros((b, 1), np.int32)
        # admission order per slot: a dry pool evicts the newest sequence
        self._admit_seq = np.zeros(b, np.int64)
        self._admit_counter = 0
        self._evicted: List[Request] = []   # awaiting readmission
        # streaming hook: called as on_emit(req, [tokens]) from the decode
        # loop the moment tokens are appended (the orchestrator's detok /
        # per-token callbacks hang off this)
        self.on_emit: Optional[Callable[[Request, List[int]], None]] = None
        self._rng = np.random.default_rng(scfg.seed)
        # legacy ``stats`` surface, backed by the shared metrics registry
        # (every key is a registry counter/gauge named "engine.<key>")
        self.stats = StatsView(self.metrics, prefix="engine.")
        self.stats.bind_counters("prefills", "decode_steps", "tokens",
                                 "rejected", "evictions")
        self.stats.bind_gauges("peak_live_pages", "kv_cache_bytes")
        self.stats["kv_cache_bytes"] = self.kv_cache_bytes()

    # ---- cache footprint ----
    def _kv_bytes(self, pool_frac: float = 1.0, cache=None) -> int:
        """Sum KV-cache leaf bytes across any cache layout by leaf name
        (``k``/``v``/scales/cross-K/V at any depth — no layout-specific
        key assumptions).  ``pool_frac`` scales page-pool leaves (paged
        layout) by an allocated-page fraction; cross-K/V does not page.
        ``cache`` defaults to the engine's target cache (the speculative
        engine also passes its ring-layout draft cache, where
        ``pool_frac`` must stay 1.0)."""

        total = 0.0
        paged = self.paged and cache is None

        def visit(kp, leaf):
            nonlocal total
            name = str(getattr(kp[-1], "key", getattr(kp[-1], "idx", kp[-1])))
            if name not in _KV_LEAF_NAMES or not hasattr(leaf, "dtype"):
                return
            nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            if paged and name in _POOL_LEAF_NAMES:
                nbytes *= pool_frac
            total += nbytes

        jax.tree_util.tree_map_with_path(
            visit, dict(self.cache if cache is None else cache))
        return int(total)

    def kv_cache_bytes(self) -> int:
        """Reserved HBM footprint of the attention K/V state (codes +
        scales + cross-K/V), for every layout."""
        return self._kv_bytes()

    def kv_cache_live_bytes(self) -> int:
        """Footprint counting only allocated pages for the paged layout
        (== reserved for ring, which preallocates everything)."""
        if not self.paged:
            return self._kv_bytes()
        return self._kv_bytes(self.allocator.live_pages / self.num_pages)

    def kv_cache_peak_live_bytes(self) -> int:
        """High-water live-page footprint over the served run (== reserved
        for ring)."""
        if not self.paged:
            return self._kv_bytes()
        return self._kv_bytes(self.stats["peak_live_pages"] / self.num_pages)

    # ---- slot management ----
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def free_slots(self) -> int:
        return sum(r is None for r in self.slot_req)

    def _admission_tokens(self, req: Request) -> np.ndarray:
        """Token sequence a (re)admission must prefill: the prompt — or,
        after a page-pool eviction, the prompt plus all-but-last emitted
        token (the last one is the readmitted slot's next decode input)."""
        if req._resume is not None:
            return req._resume
        return np.asarray(req.prompt)

    def _reserve(self, req: Request) -> Optional[Tuple[int, Any]]:
        """Host-side half of admission: claim a slot and (paged layout)
        the prompt's pool pages.  Returns (slot, prompt dst rows) or None
        when no slot / pages are free right now."""
        toks = self._admission_tokens(req)
        n = len(toks)
        if n >= self.scfg.max_len:
            raise ValueError(f"prompt length {n} >= max_len "
                             f"{self.scfg.max_len}; reject before admission")
        slot = self._free_slot()
        if slot is None:
            return None
        dst_rows = None
        if self.paged:
            ps = self.allocator.page_size
            if self.scfg.page_overcommit:
                worst = 0   # admit on current demand; dry pool evicts
            else:
                # admission control reserves the worst case this request
                # can grow to; allocation itself stays on-demand (live
                # bytes track actual tokens), and the reservation
                # invariant guarantees the growth allocs in step() can
                # never fail
                worst = self._worst_pages(req)
                if self._committed + worst > self.num_pages - 1:
                    return None
            pages = self.allocator.alloc(pages_for(n + 1, ps))
            if pages is None:       # non-overcommit: unreachable under
                return None         # the reservation invariant
            self._committed += worst
            self._slot_commit[slot] = worst
            self.slot_pages[slot] = sp = SlotPages(ps, pages)
            self._table[slot] = sp.table_row(self._pmax)
            self.cache["page_table"] = jnp.asarray(self._table)
            t = np.arange(n)
            dst_rows = np.asarray(pages, np.int64)[t // ps] * ps + t % ps
        self.slot_req[slot] = req
        self.slot_pos[slot] = n
        self._admit_counter += 1
        self._admit_seq[slot] = self._admit_counter
        return slot, dst_rows

    def _install(self, req: Request, slot: int, dst_rows, prefix,
                 row: int) -> None:
        """Device + bookkeeping half of admission: insert prefix row
        ``row`` into ``slot``, sample the first token, finish prompt-only
        requests."""
        n = int(self.slot_pos[slot])
        dst = None
        if dst_rows is not None:
            # pad to the prefix bucket width; padding rows land on the
            # trash row 0
            w = jax.tree_util.tree_leaves(
                prefix["cache"]["blocks"])[0].shape[2]
            dst = np.zeros(w, np.int64)
            dst[:n] = dst_rows
        self.cache = self.engine.insert(prefix, self.cache, slot, row,
                                        dst_rows=dst)
        req.timing.setdefault("insert_done", time.perf_counter())
        self.stats["prefills"] += 1
        if req._resume is not None:
            # recompute-on-readmit: the stream already holds every token
            # up to out_tokens[-1]; decode continues from it
            req._resume = None
            self.last_tok[slot, 0] = req.out_tokens[-1]
            return
        logits = np.asarray(prefix["logits"])[row]
        tok = int(self._sample(logits[None], [self._req_temp(req)])[0])
        self.last_tok[slot, 0] = tok
        self._emit(req, [tok])
        # prompt-only requests (max_new <= 1, or immediate EOS) finish at
        # admission — no decode tick, slot and pages free right away
        if (len(req.out_tokens) >= req.max_new
                or req.out_tokens[-1] == self.scfg.eos_id):
            req.done = True
            self._free_request_slot(slot)

    def add_request(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False if no slot (or, paged,
        not enough free pages) — the request stays queued.  Prompts that
        can never fit (``serve`` rejects these up front) are a caller
        error here: raising beats silently corrupting the page
        accounting."""
        return all(self.add_requests([req]))

    def add_requests(self, reqs: Sequence[Request]) -> List[bool]:
        """Batched admission: reserve a slot per request, then run ONE
        bucketed prefill over every admitted prompt and insert per row.
        Returns per-request admission flags; reservation stops at the
        first request that doesn't fit (FIFO order is preserved)."""
        toks = [self._admission_tokens(r) for r in reqs]
        admitted: List[Tuple[Request, int, Any, int]] = []
        ok = [False] * len(reqs)
        for j, req in enumerate(reqs):
            if not self.engine.bucketed and admitted:
                break   # exact-length prefill: one prompt per call
            r = self._reserve(req)
            if r is None:
                break   # no slot/pages: later entries wait for this one
            admitted.append((req, r[0], r[1], j))
            ok[j] = True
        if not admitted:
            return ok
        now = time.perf_counter()
        for req, _, _, _ in admitted:
            sub = req.timing.setdefault("submit", now)
            if "admit" not in req.timing:   # first admission only: a
                req.timing["admit"] = now   # readmit isn't a queue wait
                if self.tracer.enabled and now > sub:
                    self.tracer.record("queue.wait", sub, now, cat="queue",
                                       uid=req.uid)
        if self.engine.bucketed:
            bucket = self.engine.bucket_for(max(len(toks[j])
                                                for _, _, _, j in admitted))
            pad = np.zeros((len(admitted), bucket), np.int32)
            lens = np.zeros(len(admitted), np.int32)
            for row, (_, _, _, j) in enumerate(admitted):
                pad[row, :len(toks[j])] = toks[j]
                lens[row] = len(toks[j])
            prefix = self.engine.prefill(self.params, pad, lens)
        else:
            (_, _, _, j0) = admitted[0]
            prefix = self.engine.prefill(
                self.params, np.asarray(toks[j0], np.int32)[None])
        done = time.perf_counter()
        for row, (req, slot, dst_rows, _) in enumerate(admitted):
            req.timing.setdefault("prefill_done", done)
            self._install(req, slot, dst_rows, prefix, row)
        return ok

    def _worst_pages(self, req: Request) -> int:
        """Worst-case page demand of ``req``: its admission tokens plus
        the remaining max_new budget, capped by max_len (the engine stops
        a slot before max_len) and floored at prompt + 1 — admission
        always allocates a page for the first decode append, so the
        reservation must cover it even when max_new is 0."""
        s = len(self._admission_tokens(req))
        remaining = max(req.max_new - len(req.out_tokens), 0)
        tokens = min(max(s + remaining, s + 1), self.scfg.max_len)
        return pages_for(tokens, self.allocator.page_size)

    def _free_request_slot(self, slot: int) -> None:
        """Release a finished request's slot (paged: return its pages to
        the allocator immediately and point the slot at the trash page)."""
        req = self.slot_req[slot]
        if req is not None and req.done:    # eviction frees too, but an
            req.timing.setdefault(          # evicted request isn't done
                "finish", time.perf_counter())
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0
        if self.paged:
            self._committed -= self._slot_commit[slot]
            self._slot_commit[slot] = 0
            self.allocator.free(self.slot_pages[slot].pages)
            self.slot_pages[slot] = SlotPages(self.allocator.page_size)
            self._table[slot] = 0
            self.cache["page_table"] = jnp.asarray(self._table)
            # park the idle slot's write position on the trash page
            self.cache["pos"] = self.cache["pos"].at[slot].set(0)

    def _evict_newest(self) -> Optional[int]:
        """Pool-dry graceful degradation (``page_overcommit``): evict the
        most recently admitted active sequence — free its slot and pages,
        stash its progress for recompute-on-readmit, and requeue it.
        Returns the freed slot, or None with nothing left to evict."""
        cands = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not cands:
            return None
        slot = max(cands, key=lambda i: self._admit_seq[i])
        req = self.slot_req[slot]
        req._resume = np.concatenate(
            [np.asarray(req.prompt, np.int64),
             np.asarray(req.out_tokens[:-1], np.int64)])
        self._free_request_slot(slot)
        self._evicted.append(req)
        self.stats["evictions"] += 1
        return slot

    def _grow_pages(self, active: List[int], target) -> None:
        """Allocate pages so each active slot can write rows up to
        ``target(i) - 1`` this tick.  Under ``page_overcommit`` a dry
        pool evicts the newest sequence instead of raising (the evicted
        slot may be the growing one — its ``slot_req`` goes None and the
        caller refilters ``active``)."""
        grew = False
        for i in active:
            while self.slot_req[i] is not None:
                need = self.slot_pages[i].pages_needed(int(target(i)))
                if not need:
                    break
                pages = self.allocator.alloc(need)
                if pages is not None:
                    self.slot_pages[i].pages.extend(pages)
                    self._table[i] = self.slot_pages[i].table_row(self._pmax)
                    grew = True
                    break
                if not self.scfg.page_overcommit:
                    # the admission reservation makes this unreachable
                    raise RuntimeError(
                        "paged KV pool exhausted mid-decode — the "
                        "admission reservation invariant was violated "
                        "(pages allocated outside the engine?)")
                if self._evict_newest() is None:
                    raise RuntimeError(
                        "paged KV pool exhausted with no sequence left "
                        "to evict")
                grew = True
        if grew:
            self.cache["page_table"] = jnp.asarray(self._table)
        self.stats["peak_live_pages"] = max(
            self.stats["peak_live_pages"], self.allocator.live_pages)

    def _req_temp(self, req: Request) -> float:
        """Resolved sampling temperature for ``req`` (per-request override
        falls back to the engine-wide default)."""
        return (self.scfg.temperature if req.temperature is None
                else req.temperature)

    def _sample(self, logits: np.ndarray,
                temps: Optional[np.ndarray] = None) -> np.ndarray:
        """Sample next tokens row-wise.  ``temps`` is a per-row temperature
        vector (None = the engine-wide default for every row); rows at
        temperature <= 0 are greedy, the rest are softmax samples at their
        own temperature."""
        logits = logits[..., : self.cfg.vocab]
        greedy = logits.argmax(-1)
        if temps is None:
            temps = np.full(greedy.shape, self.scfg.temperature)
        temps = np.broadcast_to(np.asarray(temps, np.float32), greedy.shape)
        hot = temps > 0
        if not hot.any():
            return greedy
        t = np.where(hot, temps, 1.0)[..., None]
        p = jax.nn.softmax(jnp.asarray(logits) / t, -1)
        c = np.cumsum(np.asarray(p), -1)
        u = self._rng.random(c.shape[:-1] + (1,))
        sampled = (c < u).sum(-1)
        return np.where(hot, sampled, greedy)

    def _emit(self, req: Request, toks: List[int]) -> None:
        """Append newly decoded tokens to ``req`` and stream them through
        the ``on_emit`` hook."""
        if toks:
            req.timing.setdefault("first_token", time.perf_counter())
        req.out_tokens.extend(toks)
        self.stats["tokens"] += len(toks)
        if self.on_emit is not None:
            self.on_emit(req, toks)

    # ---- one decode tick for the whole batch ----
    def step(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        if self.paged:
            # grow page lists so every active slot has a page for the
            # token this tick writes at its own position
            self._grow_pages(active, lambda i: self.slot_pos[i] + 1)
            active = [i for i in active if self.slot_req[i] is not None]
            if not active:
                return
        self.cache["tok"] = jnp.asarray(self.last_tok)
        # guard-armed engines retain the pre-generate state (donate=False)
        # so a quarantined slot can be re-decoded up the precision ladder
        prev = self.cache if self.guard is not None else None
        self.cache, logits = self.engine.generate(self.params, self.cache)
        logits = np.asarray(logits)
        if self.faults is not None or self.guard is not None:
            logits = np.array(logits, copy=True)   # writable host copy
            poisons = {}
            if self.faults is not None:
                poisons = self.faults.poison_round(
                    {i: self.slot_req[i].uid for i in active})
                for i in poisons:
                    logits[i] = np.nan
            if self.guard is not None:
                self.guard.check_round(prev, logits, active, poisons)
                # ladder-exhausted requests terminated inside the guard:
                # reclaim their slot + pages, drop them from this round
                for i in active:
                    r = self.slot_req[i]
                    if r is not None and r.done:
                        self._free_request_slot(i)
                active = [i for i in active
                          if self.slot_req[i] is not None]
        temps = np.asarray([0.0 if r is None else self._req_temp(r)
                            for r in self.slot_req], np.float32)
        with self.tracer.span("host.sample"):
            toks = self._sample(logits, temps)
        self.stats["decode_steps"] += 1
        for i in active:
            req = self.slot_req[i]
            tok = int(toks[i])
            self.last_tok[i, 0] = tok
            self.slot_pos[i] += 1
            self._emit(req, [tok])
            eos = self.scfg.eos_id
            if (len(req.out_tokens) >= req.max_new
                    or (eos is not None and tok == eos)
                    or self.slot_pos[i] >= self.scfg.max_len - 1):
                req.done = True
                self._free_request_slot(i)

    def abort(self, req: Request, error: Optional[str] = None) -> None:
        """Terminally release ``req`` from outside the decode loop
        (deadline expiry, cancellation, crash containment): free its
        slot and pages if it is active, drop it from the eviction
        requeue, and mark it done.  Idempotent; must run on the thread
        driving the engine (the orchestrator's scheduler thread)."""
        req.done = True
        if error is not None and req.error is None:
            req.error = error
        for i, r in enumerate(self.slot_req):
            if r is req:
                self._free_request_slot(i)   # stamps finish (req.done)
                return
        if req in self._evicted:
            self._evicted.remove(req)
        req.timing.setdefault("finish", time.perf_counter())

    def _reject_reason(self, req: Request) -> Optional[str]:
        """Why ``req`` can NEVER be admitted (None = admissible once a
        slot/pages free up).  Subclasses add checks (the speculative
        engine needs chunk headroom and greedy sampling)."""
        n = len(self._admission_tokens(req))
        if n >= self.scfg.max_len:
            return (f"prompt length {n} >= "
                    f"max_len {self.scfg.max_len}")
        if self.paged:
            if self.scfg.page_overcommit:
                if pages_for(n + 1, self.allocator.page_size) \
                        > self.num_pages - 1:
                    return ("prompt alone needs more pages than the "
                            f"pool holds ({self.num_pages - 1} allocatable)")
            elif self._worst_pages(req) > self.num_pages - 1:
                return ("request worst case needs more pages than the "
                        f"pool holds ({self.num_pages - 1} allocatable)")
        return None

    def _admit(self, queue: List[Request]) -> None:
        """Admit every currently admissible queued request, scanning past
        blocked entries (no head-of-line blocking: an oversized or
        page-starved head must not starve slots later entries can fill).
        FIFO priority is kept — earlier entries get first pick."""
        i = 0
        while i < len(queue):
            req = queue[i]
            reject = self._reject_reason(req)
            if reject is not None:
                req.done = True
                req.error = reject
                now = time.perf_counter()
                req.timing.setdefault("submit", now)
                req.timing.setdefault("finish", now)
                self.stats["rejected"] += 1
                queue.pop(i)
                continue
            if self.add_request(req):
                queue.pop(i)
                continue
            i += 1

    def serve(self, requests: List[Request], max_ticks: int = 10_000
              ) -> Dict[str, Any]:
        """Run to completion with continuous batching.  Durations come
        from ``time.perf_counter()`` (monotonic, same clock as the
        tracer/orchestrator stamps) — never ``time.time()``."""
        queue = list(requests)
        t0 = time.perf_counter()
        for r in queue:                 # sync path: batch entry == submit
            r.timing.setdefault("submit", t0)
        ticks = 0
        while (queue or self._evicted
               or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            if self._evicted:   # evicted sequences readmit first (oldest)
                queue[0:0] = self._evicted
                self._evicted.clear()
            with self.tracer.span("serve.admit"):
                self._admit(queue)
            with self.tracer.span("serve.step"):
                self.step()
            ticks += 1
        dt = time.perf_counter() - t0
        # live bytes at drain are ~0 by construction (every finished
        # request returns its pages); the peak is the meaningful figure
        return {"wall_s": dt, **self.stats,
                "kv_peak_live_bytes": self.kv_cache_peak_live_bytes(),
                "tok_per_s": self.stats["tokens"] / max(dt, 1e-9)}
