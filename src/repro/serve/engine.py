"""Batched serving engine: continuous batching over a TALU-style
transprecision model (posit-packed weights decoded on load).

Slot-based continuous batching: a fixed batch of B slots; finished
sequences free their slot and the next queued request is prefilled into it
(its KV rows overwritten) while other slots keep decoding — the standard
production pattern (vLLM-style) reduced to its JAX-native core:

* ``decode_step`` is ONE jitted program for the whole batch (slots carry
  per-slot positions via the shared cache ``pos`` + per-slot offsets);
* prefill for a joining request runs as a separate jitted call whose cache
  writes are merged into the live batch cache at its slot index;
* sampling: greedy or temperature (per-request).

For single-host examples this runs real tokens end-to-end; the multi-pod
decode path (KV-sharded + LSE combine) is exercised by the dry-run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.transprecision import BF16, TCPolicy, get_policy, kv_storage
from ..models import lm
from ..models.serve_model import decode_step, init_cache, prefill


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0     # 0 => greedy
    seed: int = 0
    eos_id: Optional[int] = None
    # KV-cache storage override (f32|bf16|posit16|posit8|posit4); None
    # keeps the policy's own kv_format / legacy packed_kv resolution.
    kv_format: Optional[str] = None


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: lm.ModelCfg, params, scfg: ServeConfig,
                 policy: TCPolicy = BF16):
        self.cfg = cfg
        self.scfg = scfg
        self.policy = get_policy(policy)
        if scfg.kv_format is not None:
            self.policy = dataclasses.replace(
                self.policy, kv_format=scfg.kv_format,
                name=f"{self.policy.name}+kv_{scfg.kv_format}")
        self.params = params
        b, L = scfg.max_batch, scfg.max_len

        # one shared cache; per-slot sequence positions
        self.cache = init_cache(cfg, b, L, policy=self.policy)
        self.slot_pos = np.zeros(b, np.int64)         # tokens generated so far
        self.slot_req: List[Optional[Request]] = [None] * b
        self.last_tok = np.zeros((b, 1), np.int32)

        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, cfg, self.policy))
        self._prefill = jax.jit(
            lambda p, batch: prefill(p, batch, cfg, L, self.policy))
        self._rng = np.random.default_rng(scfg.seed)
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0,
                      "kv_cache_bytes": self.kv_cache_bytes()}

    def kv_cache_bytes(self) -> int:
        """HBM footprint of the attention K/V rings (codes + scales)."""
        total = 0
        for blocks in (self.cache.get("blocks", ()),
                       self.cache.get("tail", ())):
            for c in blocks:
                for name in ("k", "v", "k_scale", "v_scale"):
                    if name in c:
                        a = c[name]
                        total += int(np.prod(a.shape)) * a.dtype.itemsize
        return total

    # ---- slot management ----
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def add_request(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False if engine is full."""
        slot = self._free_slot()
        if slot is None:
            return False
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = self._prefill(self.params, {"tokens": prompt})
        # merge the single-row cache into the batch cache at ``slot``
        def merge(dst, src):
            if dst.ndim == 0:                 # pos handled below
                return dst
            if dst.shape == src.shape:        # max_batch == 1: take src
                return src.astype(dst.dtype)
            # batch axis is the first axis where the sizes differ
            ax = next(i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
                      if a != b)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=ax)
        new_cache = jax.tree.map(merge, dict(self.cache), dict(cache1))
        # shared decode position = furthest slot (exact when concurrent
        # prompts share a length — the engine pads to that in production;
        # per-slot position vectors are the general extension)
        new_cache["pos"] = jnp.maximum(self.cache["pos"], cache1["pos"])
        self.cache = new_cache
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        self.last_tok[slot, 0] = int(self._sample(np.asarray(logits))[0])
        req.out_tokens.append(int(self.last_tok[slot, 0]))
        self.stats["prefills"] += 1
        return True

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        logits = logits[..., : self.cfg.vocab]
        if self.scfg.temperature <= 0:
            return logits.argmax(-1)
        p = jax.nn.softmax(jnp.asarray(logits) / self.scfg.temperature, -1)
        c = np.cumsum(np.asarray(p), -1)
        u = self._rng.random(c.shape[:-1] + (1,))
        return (c < u).sum(-1)

    # ---- one decode tick for the whole batch ----
    def step(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        # shared-pos model: the cache pos advances for everyone; empty slots
        # just write garbage into their own rows (they are re-prefilled later)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(self.last_tok))
        toks = self._sample(np.asarray(logits))
        self.stats["decode_steps"] += 1
        for i in active:
            req = self.slot_req[i]
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self.last_tok[i, 0] = tok
            self.slot_pos[i] += 1
            self.stats["tokens"] += 1
            eos = self.scfg.eos_id
            if (len(req.out_tokens) >= req.max_new
                    or (eos is not None and tok == eos)
                    or self.slot_pos[i] >= self.scfg.max_len - 1):
                req.done = True
                self.slot_req[i] = None

    def serve(self, requests: List[Request], max_ticks: int = 10_000
              ) -> Dict[str, Any]:
        """Run to completion with continuous batching."""
        queue = list(requests)
        t0 = time.time()
        ticks = 0
        while (queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            while queue and self.add_request(queue[0]):
                queue.pop(0)
            self.step()
            ticks += 1
        dt = time.time() - t0
        return {"wall_s": dt, **self.stats,
                "tok_per_s": self.stats["tokens"] / max(dt, 1e-9)}
