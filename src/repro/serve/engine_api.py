"""Disaggregated serving-engine API: three jitted stages.

JetStream/maxtext-style split of the serving stack into separately
schedulable, separately jitted stages over one shared decode state:

    prefill(params, tokens, lengths) -> Prefix
    insert(prefix, decode_state, slot) -> decode_state
    generate(params, decode_state)    -> (decode_state, logits)

plus ``verify`` (the multi-token chunk pass speculative decoding drives)
and the rollback stages.  ``ServingEngine``/``SpeculativeEngine`` and the
async ``Orchestrator`` are thin host-side drivers over this API; the
distributed engine is the same API with a KV-sharded attention impl
plugged into the decode stages.

Design points:

* **Bucketed prefill.**  For decoder-only attention stacks, prompts are
  right-padded to a power-of-two bucket and prefilled at *bucket* width
  with per-row true lengths (``models.serve_model.prefill(true_len=...)``)
  — padded keys are causally masked to exact-zero attention contributions,
  so real rows' logits and K/V are bit-identical to an unpadded prefill.
  Mixed-length prompts share one prefill call and one compiled program per
  bucket instead of one per prompt length.
* **Prefix = bucket-width cache.**  ``prefill`` returns a ``Prefix`` pytree
  whose cache leaves are (B, bucket, ...) ring rows — never a full
  ``max_len`` cache.  On the paged layout the prompt K/V codes are
  codec-identical between the ring datapath and the pool, so ``insert``
  scatters the prefix rows straight into the slot's pool pages (the old
  ring-then-scatter intermediate max_len cache is retired).
* **One program per stage.**  ``generate`` is a single jitted program for
  the whole batch with true per-slot positions; ``insert`` is a donated
  per-slot merge touching only per-slot leaves; ``prefill`` compiles per
  (batch, bucket).  The decode state carries a ``"tok"`` leaf (B, 1) — the
  next input token per slot — which ``generate`` advances to its greedy
  argmax on-device; drivers overwrite it host-side for temperature-sampled
  rows.

Families outside the bucketed gate (sliding-window, recurrent/SSM, MoE,
audio/vlm) keep the legacy exact-length full-width prefill + whole-leaf
insert path, preserving their semantics unchanged.

Observability: constructed with a ``tracer``/``metrics`` pair
(:mod:`repro.obs`), every stage call is wrapped in *paired* stamps — a
``<stage>.dispatch`` span until the (async) stage call returns to
Python, then a ``<stage>.device`` span around ``jax.block_until_ready``
— so Python/jit-dispatch overhead is attributed separately from device
compute, and a ``jax.profiler.TraceAnnotation`` so host spans line up
with XLA traces.  With tracing disabled nothing is synchronized and the
per-call overhead is a single attribute check (the < 2 % decode-loop
bound in ``tests/test_obs.py``).
"""
from __future__ import annotations

import dataclasses
from time import perf_counter, sleep
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.transprecision import TCPolicy, get_policy
from ..models.serve_model import (decode_step, init_cache, prefill,
                                  verify_step)
from ..obs import MetricsRegistry, Tracer

_POOL_LEAF_NAMES = ("k", "v", "k_scale", "v_scale")
_SCRUB_LEAVES = ("k", "v", "k_scale", "v_scale")
_MIN_BUCKET = 16

# A Prefix is a plain pytree: {"logits": (B, vocab_pad) — next-token
# logits per prompt, "cache": prefill cache (leaf rows at bucket width),
# "length": (B,) int32 true prompt lengths}.
Prefix = Dict[str, Any]


# ---------------------------------------------------------------------------
# Rollback stages (speculative decoding)
# ---------------------------------------------------------------------------

def rollback_ring_cache(cache, new_pos, window_end, scrub_from, t: int):
    """Rewind a ring-layout cache after a verify round: set ``pos`` to
    ``new_pos`` (B,) and scrub the speculatively written rows back to
    their init values (codes/floats 0, scales 1.0).

    Scatter form, O(B·t) rows touched: per slot only the *fixed-size*
    window of the last ``t`` rows written this round — rows
    ``[window_end - t, window_end)`` — is gathered, and rows at positions
    ``>= scrub_from`` are reset while the rest write their own value back
    (no-op).  Slots with nothing to scrub pass ``scrub_from ==
    window_end``.  All indices within a slot are distinct, so the scatter
    is order-independent.  No wraparound: row index == position, which
    ``verify_step`` guarantees by refusing sliding-window configs, and
    ``window_end <= max_len`` because a round never writes past the cap.
    """
    new = jnp.asarray(new_pos, jnp.int32)
    end = jnp.maximum(jnp.asarray(window_end, jnp.int32), t)
    frm = jnp.asarray(scrub_from, jnp.int32)
    off = jnp.arange(t, dtype=jnp.int32)
    rows = end[:, None] - t + off[None, :]          # (B, t), distinct/slot
    mask = rows >= frm[:, None]                     # True => reset to init

    def scrub_block(blk, stacked):
        # blocks leaves carry a leading period-stack axis (P, B, W, ...);
        # tail leaves are plain (B, W, ...)
        out = dict(blk)
        for name in _SCRUB_LEAVES:
            if name not in blk:
                continue
            leaf = blk[name]
            nb = leaf.shape[1 if stacked else 0]
            bi = jnp.arange(nb, dtype=jnp.int32)[:, None]
            init = jnp.asarray(1.0 if name.endswith("_scale") else 0,
                               leaf.dtype)
            if stacked:                              # (P, B, W, ...)
                cur = leaf[:, bi, rows]              # (P, B, t, ...)
                m = mask.reshape((1,) + mask.shape
                                 + (1,) * (leaf.ndim - 3))
                out[name] = leaf.at[:, bi, rows].set(jnp.where(m, init, cur))
            else:                                    # (B, W, ...)
                cur = leaf[bi, rows]                 # (B, t, ...)
                m = mask.reshape(mask.shape + (1,) * (leaf.ndim - 2))
                out[name] = leaf.at[bi, rows].set(jnp.where(m, init, cur))
        return out

    new_cache = dict(cache)
    new_cache["blocks"] = tuple(scrub_block(b, True) for b in cache["blocks"])
    if "tail" in cache:
        new_cache["tail"] = tuple(scrub_block(b, False)
                                  for b in cache["tail"])
    new_cache["pos"] = new
    return new_cache


def rollback_paged_cache(cache, new_pos, scrub_rows):
    """Rewind a paged-layout cache: set ``pos`` to ``new_pos`` (B,) and
    scrub the flat pool rows in ``scrub_rows`` (fixed-size (N,) i32,
    padded with trash row 0 — writes there are benign by construction)
    back to init values.  Page-table truncation and allocator frees are
    the engine's host-side half of the rollback."""
    rows = jnp.asarray(scrub_rows, jnp.int32)

    def scrub_block(blk, stacked):
        # blocks pool leaves carry a leading period-stack axis (P, R, ...);
        # tail leaves are plain (R, ...)
        out = dict(blk)
        for name in _SCRUB_LEAVES:
            if name not in blk:
                continue
            leaf = blk[name]
            init = jnp.asarray(1.0 if name.endswith("_scale") else 0,
                               leaf.dtype)
            out[name] = (leaf.at[:, rows].set(init) if stacked
                         else leaf.at[rows].set(init))
        return out

    new_cache = dict(cache)
    new_cache["blocks"] = tuple(scrub_block(b, True) for b in cache["blocks"])
    if "tail" in cache:
        new_cache["tail"] = tuple(scrub_block(b, False)
                                  for b in cache["tail"])
    new_cache["pos"] = jnp.asarray(new_pos, jnp.int32)
    return new_cache


def _abstract_args(args):
    """Arg pytree with arrays replaced by ``jax.ShapeDtypeStruct`` —
    static python scalars (jit ``static_argnums``) pass through.  The
    energy accountant (``repro.obs.energy``) re-lowers a stage from this
    spec to cost its compiled program without holding live buffers."""
    return jax.tree_util.tree_map(
        lambda x: (jax.ShapeDtypeStruct(x.shape, x.dtype)
                   if hasattr(x, "shape") and hasattr(x, "dtype") else x),
        args)


def _slot_update(dst, src, slot):
    """Write the single-row ``src`` into ``dst`` at batch index ``slot``.
    The batch axis is the first axis where the sizes differ; identical
    shapes mean max_batch == 1 (take src).  ``src`` may be narrower than
    ``dst`` on the row axis (bucket-width prefix rows land at [0, w))."""
    if dst.shape == src.shape:
        return src.astype(dst.dtype)
    ax = next(i for i, (a, b) in enumerate(zip(dst.shape, src.shape))
              if a != b)
    return jax.lax.dynamic_update_slice_in_dim(
        dst, src.astype(dst.dtype), slot, axis=ax)


class TransprecisionEngine:
    """The three-stage engine for one (model cfg, transprecision policy):

    * ``prefill(params, tokens, lengths)`` — run a (B, bucket) prompt
      batch, returning a :data:`Prefix`;
    * ``insert(prefix, state, slot, row, dst_rows)`` — merge prefix row
      ``row`` into batch slot ``slot`` of the decode state (paged layout:
      scatter its K/V rows to the ``dst_rows`` flat pool rows);
    * ``generate(params, state)`` — one decode tick for the whole batch;
      returns ``(state, logits)`` with ``state["tok"]`` advanced to the
      greedy next token per slot;
    * ``verify(params, state, chunk)`` — the (B, T) chunk pass for
      speculative verify rounds.

    The engine owns no request/queue state — drivers do.  ``attn_impl``
    plugs a custom decode-attention (e.g. the KV-sharded distributed
    path) into ``generate``."""

    def __init__(self, cfg, policy: TCPolicy, max_batch: int, max_len: int,
                 *, num_pages: Optional[int] = None, attn_impl=None,
                 donate: Optional[bool] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 stage_prefix: str = "", faults=None, retry=None):
        self.cfg = cfg
        self.policy = get_policy(policy)
        # chaos hardening (both default None = zero-cost): ``faults`` is a
        # FaultInjector whose on_stage hook runs before every stage
        # dispatch; ``retry`` is a RetryPolicy absorbing *transient* stage
        # failures with bounded exponential backoff (serve/faults.py)
        self.faults = faults
        self.retry = retry
        # observability: spans + per-stage latency histograms while the
        # tracer is enabled (the speculative draft engine shares its
        # driver's tracer/registry under a "draft." stage prefix)
        self.tracer = tracer
        self.metrics = metrics
        self.stage_prefix = stage_prefix
        self.max_batch, self.max_len = max_batch, max_len
        self.paged = getattr(self.policy, "kv_layout", "ring") == "paged"
        self.num_pages = num_pages
        self.attn_impl = attn_impl
        # bucketed (right-padded) prefill is exact only for decoder-only
        # attention stacks; other families keep exact-length prefill
        self.bucketed = (all(bt == "attn" for bt in cfg.block_types)
                         and not cfg.window
                         and cfg.family not in ("moe", "audio", "vlm"))
        if self.paged:
            # prompts prefill through the ring datapath at bucket width
            # (identical codec to the pool) and insert scatters the rows
            # into pool pages — no intermediate max_len ring cache
            self._prefill_policy = dataclasses.replace(
                self.policy, kv_layout="ring",
                name=self.policy.name + "+prefix")
        else:
            self._prefill_policy = self.policy
        # donation keeps per-stage state updates from copying the whole
        # batch cache (ignored with a warning on CPU, so default off there)
        self._donate = ((jax.default_backend() != "cpu")
                        if donate is None else donate)
        self._prefill_jits: Dict[Any, Any] = {}
        self._insert_jits: Dict[Any, Any] = {}
        self._verify_jits: Dict[int, Any] = {}
        self._rb_ring_jits: Dict[int, Any] = {}
        # always-on per-stage invocation counters ("stage.<name>.calls" in
        # the registry — the live multiplier of the energy model's static
        # pJ/invocation table) and the first-seen abstract arg spec per
        # stage, from which the energy accountant lowers + costs the
        # stage's compiled program.  Both are cheap on the hot path: one
        # dict hit + counter inc per stage call, spec capture only once.
        self._call_counters: Dict[str, Any] = {}
        self.stage_specs: Dict[str, Any] = {}
        self._generate_jit = jax.jit(
            self._generate_impl,
            donate_argnums=(1,) if self._donate else ())
        self._rb_paged = jax.jit(
            rollback_paged_cache,
            donate_argnums=(0,) if self._donate else ())

    # ---- observability ----
    def _staged(self, stage: str, fn, *args):
        """Run one engine stage with paired host-dispatch / device-
        complete stamps.  The dispatch span covers the Python call (jit
        dispatch, and compilation on a cache miss); the device span
        covers the ``block_until_ready`` wait for the stage's outputs.
        With no enabled tracer this is a plain call — no sync, no
        stamps — so tracing-off serving keeps XLA's async dispatch."""
        name = self.stage_prefix + stage
        if self.metrics is not None:
            ctr = self._call_counters.get(name)
            if ctr is None:
                ctr = self._call_counters[name] = self.metrics.counter(
                    f"stage.{name}.calls")
            ctr.inc()
        if name not in self.stage_specs:
            self.stage_specs[name] = (fn, _abstract_args(args))
        tr = self.tracer
        if tr is None or not tr.enabled:
            if self.faults is None and self.retry is None:
                return fn(*args)
            return self._invoke(name, fn, args)
        t0 = perf_counter()
        with jax.profiler.TraceAnnotation(name):
            with tr.span(name + ".dispatch", cat="engine"):
                out = self._invoke(name, fn, args)
        t1 = perf_counter()
        with tr.span(name + ".device", cat="engine"):
            jax.block_until_ready(out)
        t2 = perf_counter()
        if self.metrics is not None:
            self.metrics.histogram(f"stage.{name}.dispatch_s").observe(
                t1 - t0)
            self.metrics.histogram(f"stage.{name}.device_s").observe(
                t2 - t1)
        return out

    def _invoke(self, name, fn, args):
        """One stage call behind the fault-injection and retry hooks
        (plain call with neither armed).  Injection raises BEFORE the
        stage dispatches, so a failed attempt never consumes donated
        buffers; only exceptions flagged ``transient`` are retried, with
        bounded exponential backoff (``stage.retries`` /
        ``stage.<name>.retries`` counters; ``stage.retry_exhausted``
        when the budget runs out and the failure propagates)."""
        faults, retry = self.faults, self.retry
        if faults is None and retry is None:
            return fn(*args)
        tries = 0
        while True:
            try:
                if faults is not None:
                    faults.on_stage(name)
                return fn(*args)
            except Exception as e:
                transient = bool(getattr(e, "transient", False))
                tries += 1
                if retry is None or not transient \
                        or tries >= retry.max_attempts:
                    if transient and retry is not None \
                            and self.metrics is not None:
                        self.metrics.counter("stage.retry_exhausted").inc()
                    raise
                if self.metrics is not None:
                    self.metrics.counter("stage.retries").inc()
                    self.metrics.counter(f"stage.{name}.retries").inc()
                sleep(retry.delay(tries - 1))

    # ---- stage: decode-state construction ----
    def init_decode_state(self) -> Dict[str, Any]:
        """Empty decode state for ``max_batch`` slots: the KV cache pytree
        with per-slot ``pos`` plus the ``"tok"`` next-input leaf.  Paged
        engines with an explicit pool size get a zero page table (the
        driver owns it)."""
        kw = {"num_pages": self.num_pages} if self.paged else {}
        state = init_cache(self.cfg, self.max_batch, self.max_len,
                           policy=self.policy, **kw)
        state["pos"] = jnp.zeros((self.max_batch,), jnp.int32)
        state["tok"] = jnp.zeros((self.max_batch, 1), jnp.int32)
        return state

    # ---- stage: prefill ----
    def bucket_for(self, s: int) -> int:
        """Prefill width for an ``s``-token prompt: the smallest power-of-
        two bucket (>= 16, <= max_len) that holds it; non-bucketed
        families prefill at the exact length."""
        if not self.bucketed:
            return s
        b = _MIN_BUCKET
        while b < s:
            b <<= 1
        return min(b, self.max_len)

    def prefill(self, params, tokens, lengths=None) -> Prefix:
        """Run a prompt batch: ``tokens`` (B, S) int32, right-padded;
        ``lengths`` (B,) true prompt lengths (None = every row is exactly
        S tokens).  Returns a :data:`Prefix`.  Compiles once per (B, S)."""
        tokens = jnp.asarray(tokens, jnp.int32)
        b, s = tokens.shape
        if lengths is not None and not self.bucketed:
            raise ValueError(
                f"{self.cfg.name} prefills at exact length only "
                "(bucketed/padded prefill needs a decoder-only attention "
                "stack); pass lengths=None")
        key = (b, s, lengths is not None)
        fn = self._prefill_jits.get(key)
        if fn is None:
            # bucketed prefixes are bucket-width caches; legacy families
            # keep the full max_len prefix the whole-leaf insert expects
            plen = s if self.bucketed else self.max_len

            def impl(p, t, l):
                logits, cache = prefill(p, {"tokens": t}, self.cfg, plen,
                                        self._prefill_policy, true_len=l)
                return {"logits": logits, "cache": cache, "length": l}

            def impl_full(p, t):
                logits, cache = prefill(p, {"tokens": t}, self.cfg, plen,
                                        self._prefill_policy)
                return {"logits": logits, "cache": cache,
                        "length": jnp.full((t.shape[0],), s, jnp.int32)}

            fn = jax.jit(impl if lengths is not None else impl_full)
            self._prefill_jits[key] = fn
        if lengths is not None:
            return self._staged("prefill", fn, params, tokens,
                                jnp.asarray(lengths, jnp.int32))
        return self._staged("prefill", fn, params, tokens)

    # ---- stage: insert ----
    def insert(self, prefix: Prefix, state, slot, row=0, dst_rows=None):
        """Merge prefix row ``row`` into decode-state slot ``slot``.

        Ring layout: the prefix's bucket-width K/V rows land at rows
        [0, bucket) of the slot's ring via ``dynamic_update_slice``.
        Paged layout: they scatter directly to the ``dst_rows`` flat pool
        rows ((N,) i32, padded with trash row 0) — the prefix is never
        widened to max_len.  Donated; compiles once per (bucket, N)."""
        fn = self._insert_jits.get("fn")
        if fn is None:
            fn = jax.jit(self._insert_impl,
                         donate_argnums=(0,) if self._donate else (),
                         static_argnums=(5,))
            self._insert_jits["fn"] = fn
        dst = (None if dst_rows is None
               else jnp.asarray(dst_rows, jnp.int32))
        return self._staged(
            "insert", fn, state, prefix["cache"],
            jnp.asarray(prefix["length"], jnp.int32),
            jnp.asarray(slot, jnp.int32), jnp.asarray(row, jnp.int32),
            dst is None, dst)

    def _insert_impl(self, state, pcache, length, slot, row, ring, dst_rows):
        def merge_block(dstb, srcb, stacked):
            out = {}
            for name, d in dstb.items():
                src = srcb[name]
                # select prefix batch row `row`: (P, 1, w, ...) / (1, w, ...)
                s1 = jax.lax.dynamic_slice_in_dim(
                    src, row, 1, axis=1 if stacked else 0)
                if not ring and name in _POOL_LEAF_NAMES:
                    n = dst_rows.shape[0]
                    if stacked:        # (P, R, ...) <- (P, 1, w, ...)
                        out[name] = d.at[:, dst_rows].set(
                            s1[:, 0, :n].astype(d.dtype))
                    else:              # (R, ...) <- (1, w, ...)
                        out[name] = d.at[dst_rows].set(
                            s1[0, :n].astype(d.dtype))
                else:
                    out[name] = _slot_update(d, s1, slot)
            return out

        new_state = dict(state)
        new_state["pos"] = state["pos"].at[slot].set(
            length[row].astype(state["pos"].dtype))
        new_state["blocks"] = tuple(
            merge_block(d, s, True)
            for d, s in zip(state["blocks"], pcache["blocks"]))
        if "tail" in state:
            new_state["tail"] = tuple(
                merge_block(d, s, False)
                for d, s in zip(state["tail"], pcache["tail"]))
        # any other top-level per-slot state (e.g. audio "memory") merges
        # generically; page_table/tok are driver-owned, pos handled above
        for name, d in state.items():
            if name in ("pos", "blocks", "tail", "page_table", "tok"):
                continue
            if name in pcache:
                s1 = jax.lax.dynamic_slice_in_dim(pcache[name], row, 1, 0)
                new_state[name] = _slot_update(d, s1, slot)
        return new_state

    # ---- stage: generate ----
    def _generate_impl(self, params, state):
        tok = state["tok"]
        logits, new_state = decode_step(params, state, tok, self.cfg,
                                        self.policy,
                                        attn_impl=self.attn_impl)
        new_state["tok"] = jnp.argmax(
            logits[..., : self.cfg.vocab], axis=-1).astype(jnp.int32)[:, None]
        return new_state, logits

    def generate(self, params, state):
        """One decode tick for every slot: feeds ``state["tok"]``, writes
        each slot's K/V row at its own position, advances ``pos`` and
        ``tok`` (greedy argmax — drivers overwrite sampled rows).
        Returns ``(new_state, logits (B, vocab_pad))``.  Donates
        ``state``."""
        return self._staged("generate", self._generate_jit, params, state)

    # ---- stage: verify (speculative rounds) ----
    def verify(self, params, state, chunk):
        """Score a (B, T) draft chunk in one target-precision pass
        (``models.serve_model.verify_step``): token t of slot b is scored
        and its K/V row written at position ``pos[b] + t``.  Returns
        ``(new_state, logits (B, T, vocab_pad))``; ``state["tok"]`` is
        left for the driver to set after acceptance.  Compiles per T."""
        chunk = jnp.asarray(chunk, jnp.int32)
        t = chunk.shape[1]
        fn = self._verify_jits.get(t)
        if fn is None:
            def impl(p, c, tk):
                logits, nc = verify_step(p, c, tk, self.cfg, self.policy)
                return nc, logits
            fn = jax.jit(impl, donate_argnums=(1,) if self._donate else ())
            self._verify_jits[t] = fn
        return self._staged("verify", fn, params, state, chunk)

    # ---- stage: rollback ----
    def rollback_ring(self, state, new_pos, window_end, scrub_from, t: int):
        """Jitted :func:`rollback_ring_cache` (compiled per window ``t``)."""
        fn = self._rb_ring_jits.get(t)
        if fn is None:
            fn = jax.jit(lambda c, n, e, f: rollback_ring_cache(c, n, e, f, t),
                         donate_argnums=(0,) if self._donate else ())
            self._rb_ring_jits[t] = fn
        return self._staged("rollback", fn, state,
                            np.asarray(new_pos, np.int32),
                            np.asarray(window_end, np.int32),
                            np.asarray(scrub_from, np.int32))

    def rollback_paged(self, state, new_pos, scrub_rows):
        """Jitted :func:`rollback_paged_cache`."""
        return self._staged("rollback", self._rb_paged, state,
                            np.asarray(new_pos, np.int32),
                            jnp.asarray(scrub_rows, jnp.int32))
