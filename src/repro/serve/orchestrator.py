"""Async serving orchestrator over the three-stage engine API.

The engine (:mod:`repro.serve.engine`) is a synchronous slot machine:
``add_requests`` prefills+inserts, ``step`` generates one round for every
active slot.  This module wraps it JetStream-style with the HOST-side
concerns a real serving deployment has, overlapped with device compute on
background threads:

* a **submission queue** with backpressure — a bounded semaphore caps the
  number of requests in flight (queued + decoding); ``submit`` blocks up
  to an admission timeout and returns ``False`` instead of growing the
  queue without bound;
* a **scheduler thread** that drains submissions, groups compatible
  prompts into one bucketed-length prefill batch (``engine.add_requests``
  right-pads to a shared power-of-two bucket), runs the free-slot decode
  loop, requeues pool-dry evictions at the front of the line, and retires
  finished slots;
* a **detokenizer thread** that turns emitted token batches into text and
  fires per-token streaming callbacks, so Python-side string work never
  blocks the next ``generate`` dispatch.

Tokenisation is pluggable (``tokenize``/``detokenize`` callables); the
default is a byte-level codec clipped to the model vocab, which is enough
for the synthetic-data models this repo trains.  Timing is recorded
host-side per emission (`submit`/first-token/finish ``perf_counter``
stamps — monotonic and comparable across threads), so the serving
benchmark can derive TTFT and inter-token latency percentiles without
touching the engine.

Telemetry rides the engine's :class:`repro.obs.MetricsRegistry` under the
``orch.`` prefix (``orch.submitted`` / ``finished`` / ``rejected`` /
``admission_timeouts`` counters, ``orch.queue_depth`` gauge) and the
engine's tracer: scheduler-loop segments get host spans (``orch.pull``,
``orch.admit``, ``orch.step``, ``orch.retire``, ``orch.idle``) and the
detokenizer thread gets ``cat="detok"`` spans, which the stage-breakdown
report counts as concurrent rather than wall-clock.

Threading contract: the engine is only ever touched from the scheduler
thread; ``submit``/``wait`` are safe from any thread.  Callbacks run on
the detokenizer thread and must not call back into the orchestrator
(except ``submit``).
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from ..obs import StatsView
from .engine import Request, ServingEngine

__all__ = ["OrchestratorConfig", "StreamingRequest", "Orchestrator"]


@dataclasses.dataclass
class OrchestratorConfig:
    """Host-side serving knobs (the device-side ones live in ServeConfig).

    max_queue: backpressure cap on requests in flight (queued + active).
    admission_timeout_s: default ``submit`` blocking time once the queue
        is full; ``submit`` returns False on expiry instead of enqueueing.
    batch_window_s: how long the scheduler lingers after the first
        pending prompt to coalesce more arrivals into one bucketed
        prefill batch (0 = admit immediately).
    poll_interval_s: scheduler sleep when there is nothing to do.
    detokenize: decode emitted tokens to text on the detokenizer thread
        (False streams token ids only; text fields stay empty).
    ttft_slo_s / itl_slo_s: latency SLO thresholds.  When set, every
        finished request's TTFT (and every inter-token gap) is checked
        against them and ``orch.slo.ttft_violations`` /
        ``orch.slo.itl_violations`` counters tick next to the matching
        ``*_total`` denominators.
    request_log: path of a JSONL file appended one line per terminal
        request (finished or rejected): uid, token count, error, TTFT
        and the full lifecycle decomposition (queue wait / prefill /
        insert / decode seconds from the engine's per-request stamps).
    """
    max_queue: int = 64
    admission_timeout_s: float = float("inf")
    batch_window_s: float = 0.0
    poll_interval_s: float = 0.001
    detokenize: bool = True
    ttft_slo_s: Optional[float] = None
    itl_slo_s: Optional[float] = None
    request_log: Optional[str] = None


@dataclasses.dataclass(eq=False)
class StreamingRequest:
    """One streaming generation request.

    ``prompt`` may be text (tokenized host-side) or a token-id sequence.
    ``on_token(sreq, token_ids, text_piece)`` fires on the detokenizer
    thread once per emission batch — batches hold >1 token under
    speculative decoding because accepted drafts commit together.
    """
    prompt: Union[str, Sequence[int]]
    max_new: int = 32
    temperature: Optional[float] = None   # None inherits ServeConfig's
    on_token: Optional[Callable[["StreamingRequest", List[int], str], None]] = None

    # outputs / telemetry (filled in by the orchestrator)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    out_text: str = ""
    error: Optional[str] = None
    submit_t: float = 0.0
    token_t: List[float] = dataclasses.field(default_factory=list)
    finish_t: float = 0.0
    _req: Optional[Request] = dataclasses.field(default=None, repr=False)
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the stream finishes; True if it did."""
        return self._done.wait(timeout)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit-to-first-token latency, once at least one token landed."""
        return self.token_t[0] - self.submit_t if self.token_t else None

    def itl_s(self) -> List[float]:
        """Inter-token gaps (speculative batches share one stamp → 0s)."""
        return [b - a for a, b in zip(self.token_t, self.token_t[1:])]

    def lifecycle(self) -> Dict[str, float]:
        """The engine's per-request ``perf_counter`` stamps, in lifecycle
        order (submit → admit → prefill_done → insert_done → first_token
        → finish).  Rejected requests carry only submit + finish; keys a
        request never reached are absent."""
        timing = self._req.timing if self._req is not None else {}
        order = ("submit", "admit", "prefill_done", "insert_done",
                 "first_token", "finish")
        return {k: timing[k] for k in order if k in timing}

    def lifecycle_deltas(self) -> Dict[str, float]:
        """TTFT decomposition in seconds relative to submit: queue wait
        (submit→admit), prefill (admit→prefill_done), insert
        (prefill_done→insert_done plus first-token sampling up to
        first_token), decode (first_token→finish), total."""
        t = self.lifecycle()
        out: Dict[str, float] = {}
        if "admit" in t:
            out["queue_wait_s"] = t["admit"] - t["submit"]
        if "prefill_done" in t and "admit" in t:
            out["prefill_s"] = t["prefill_done"] - t["admit"]
        if "insert_done" in t and "prefill_done" in t:
            out["insert_s"] = t["insert_done"] - t["prefill_done"]
        if "first_token" in t:
            out["ttft_s"] = t["first_token"] - t["submit"]
        if "finish" in t:
            out["total_s"] = t["finish"] - t["submit"]
            if "first_token" in t:
                out["decode_s"] = t["finish"] - t["first_token"]
        return out


def _default_tokenize(vocab: int) -> Callable[[str], List[int]]:
    def tok(text: str) -> List[int]:
        return [min(b, vocab - 1) for b in text.encode("utf-8")]
    return tok


def _default_detokenize(vocab: int) -> Callable[[List[int]], str]:
    del vocab
    def detok(toks: List[int]) -> str:
        return bytes(t % 256 for t in toks).decode("utf-8", errors="replace")
    return detok


class Orchestrator:
    """Threaded request orchestrator over a ServingEngine.

    Usage::

        with Orchestrator(engine) as orch:
            sreq = StreamingRequest("hello", max_new=16,
                                    on_token=lambda r, ids, s: print(s))
            assert orch.submit(sreq)
            sreq.wait()
    """

    def __init__(self, engine: ServingEngine,
                 ocfg: OrchestratorConfig = OrchestratorConfig(), *,
                 tokenize: Optional[Callable[[str], List[int]]] = None,
                 detokenize: Optional[Callable[[List[int]], str]] = None):
        if ocfg.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {ocfg.max_queue}")
        self.engine = engine
        self.ocfg = ocfg
        vocab = engine.cfg.vocab
        self.tokenize = tokenize or _default_tokenize(vocab)
        self.detokenize = detokenize or _default_detokenize(vocab)

        self._slots = threading.BoundedSemaphore(ocfg.max_queue)
        self._submitted: "queue.Queue[StreamingRequest]" = queue.Queue()
        self._stream_q: "queue.Queue[tuple]" = queue.Queue()
        self._by_req: Dict[int, StreamingRequest] = {}  # id(Request) -> sreq
        self._closed = False
        self._uid = 0
        self._stop = threading.Event()
        self.tracer = engine.tracer
        self.metrics = engine.metrics
        self.stats = StatsView(self.metrics, prefix="orch.")
        self.stats.bind_counters("submitted", "finished", "rejected",
                                 "admission_timeouts")
        self._queue_depth = self.metrics.gauge("orch.queue_depth")
        # lifecycle latency distributions + SLO accounting (scheduler
        # thread only; Histogram.observe is locked anyway)
        self._h_ttft = self.metrics.histogram("orch.ttft_s")
        self._h_itl = self.metrics.histogram("orch.itl_s")
        self._h_qwait = self.metrics.histogram("orch.queue_wait_s")
        self._slo = {k: self.metrics.counter(f"orch.slo.{k}")
                     for k in ("ttft_total", "ttft_violations",
                               "itl_total", "itl_violations")}
        self._reqlog = (open(ocfg.request_log, "a")
                        if ocfg.request_log else None)
        self._reqlog_lock = threading.Lock()

        engine.on_emit = self._on_emit       # runs on the scheduler thread
        self._sched = threading.Thread(target=self._scheduler_loop,
                                       name="orch-scheduler", daemon=True)
        self._detok = threading.Thread(target=self._detok_loop,
                                       name="orch-detok", daemon=True)
        self._sched.start()
        self._detok.start()

    # ---- submission side (any thread) ----
    def submit(self, sreq: StreamingRequest,
               timeout: Optional[float] = None) -> bool:
        """Enqueue a request; False if backpressure held past ``timeout``
        (default: the config admission timeout)."""
        if self._closed:
            raise RuntimeError("orchestrator is closed")
        if timeout is None:
            timeout = self.ocfg.admission_timeout_s
        blocking = timeout > 0
        if not self._slots.acquire(
                blocking,
                None if timeout == float("inf") or not blocking else timeout):
            self.stats["admission_timeouts"] += 1
            return False
        sreq.submit_t = time.perf_counter()
        self.stats["submitted"] += 1
        self._submitted.put(sreq)
        self._queue_depth.set(self._submitted.qsize())
        return True

    # ---- scheduler thread ----
    def _on_emit(self, req: Request, toks: List[int]) -> None:
        sreq = self._by_req.get(id(req))
        if sreq is None:
            return
        now = time.perf_counter()
        sreq.token_t.extend([now] * len(toks))
        self._stream_q.put(("toks", sreq, list(toks)))

    def _finish(self, sreq: StreamingRequest, error: Optional[str] = None):
        sreq.error = error
        sreq.finish_t = time.perf_counter()
        if sreq._req is not None:
            # rejects the orchestrator filters itself never reach the
            # engine's stamping paths; backfill the terminal stamps so
            # every terminal request has submit+finish
            sreq._req.timing.setdefault("submit", sreq.submit_t)
            sreq._req.timing.setdefault("finish", sreq.finish_t)
        self._observe_slo(sreq)
        self.stats["rejected" if error else "finished"] += 1
        self._stream_q.put(("done", sreq))
        self._slots.release()

    def _observe_slo(self, sreq: StreamingRequest) -> None:
        """Latency histograms + SLO violation counters for one terminal
        request (scheduler thread)."""
        d = sreq.lifecycle_deltas()
        if "queue_wait_s" in d:
            self._h_qwait.observe(d["queue_wait_s"])
        ttft = sreq.ttft_s
        if ttft is not None:
            self._h_ttft.observe(ttft)
            if self.ocfg.ttft_slo_s is not None:
                self._slo["ttft_total"].inc()
                if ttft > self.ocfg.ttft_slo_s:
                    self._slo["ttft_violations"].inc()
        for gap in sreq.itl_s():
            self._h_itl.observe(gap)
            if self.ocfg.itl_slo_s is not None:
                self._slo["itl_total"].inc()
                if gap > self.ocfg.itl_slo_s:
                    self._slo["itl_violations"].inc()

    def _scheduler_loop(self) -> None:
        eng, ocfg, tracer = self.engine, self.ocfg, self.tracer
        pending: deque = deque()
        while True:
            # pull new submissions; filter out the never-admissible
            fresh = False
            with tracer.span("orch.pull"):
                while True:
                    try:
                        sreq = self._submitted.get_nowait()
                    except queue.Empty:
                        break
                    sreq._req = self._to_engine_request(sreq)
                    reject = eng._reject_reason(sreq._req)
                    if reject is not None:
                        self._finish(sreq, error=reject)
                        continue
                    self._by_req[id(sreq._req)] = sreq
                    pending.append(sreq)
                    fresh = True
                # pool-dry evictions resume at the head of the line
                if eng._evicted:
                    evicted, eng._evicted = eng._evicted, []
                    for r in reversed(evicted):
                        pending.appendleft(self._by_req[id(r)])
                self._queue_depth.set(len(pending))
            if fresh and ocfg.batch_window_s > 0 and eng.free_slots():
                with tracer.span("orch.idle", kind="batch_window"):
                    time.sleep(ocfg.batch_window_s)   # coalesce one batch
                continue
            # bucketed admission: one shared-bucket prefill per batch
            if pending and eng.free_slots():
                with tracer.span("orch.admit", n=len(pending)):
                    batch = [pending.popleft() for _ in
                             range(min(len(pending), eng.free_slots()))]
                    ok = eng.add_requests([s._req for s in batch])
                    failed = [s for s, admitted in zip(batch, ok)
                              if not admitted]
                    for s in reversed(failed):   # infeasible right now:
                        pending.appendleft(s)    # retry in FIFO order
                self._queue_depth.set(len(pending))
            active = any(r is not None for r in eng.slot_req)
            if active:
                with tracer.span("orch.step"):
                    eng.step()
            # retire finished requests (admission can finish prompt-only
            # requests too, so scan the full map)
            with tracer.span("orch.retire"):
                done_ids = [rid for rid, s in self._by_req.items()
                            if s._req.done and s not in pending]
                for rid in done_ids:
                    s = self._by_req.pop(rid)
                    self._finish(s, error=s._req.error)
            if self._stop.is_set() and not pending and not active \
                    and self._submitted.empty() and not eng._evicted:
                self._stream_q.put(("stop",))
                return
            if not active and not pending:
                with tracer.span("orch.idle", kind="poll"):
                    time.sleep(ocfg.poll_interval_s)

    def _to_engine_request(self, sreq: StreamingRequest) -> Request:
        toks = (self.tokenize(sreq.prompt)
                if isinstance(sreq.prompt, str) else
                [int(t) for t in sreq.prompt])
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(toks, np.int32),
                      max_new=sreq.max_new, temperature=sreq.temperature)
        # the engine's lifecycle stamps start from the true submission
        # time, not the scheduler pull time, so queue wait is end-to-end
        req.timing["submit"] = sreq.submit_t
        return req

    # ---- detokenizer thread ----
    def _detok_loop(self) -> None:
        while True:
            item = self._stream_q.get()
            if item[0] == "stop":
                return
            if item[0] == "done":
                # log BEFORE _done.set(): close() joins this thread, so a
                # waiter that saw done=True is guaranteed a flushed line
                if self._reqlog is not None:
                    self._write_reqlog(item[1])
                item[1]._done.set()
                continue
            _, sreq, toks = item
            # cat="detok" → the breakdown report counts this thread's work
            # as concurrent with the scheduler, not extra wall time
            with self.tracer.span("orch.detok", cat="detok", n=len(toks)):
                sreq.out_tokens.extend(toks)
                piece = ""
                if self.ocfg.detokenize:
                    piece = self.detokenize(toks)
                    sreq.out_text += piece
                if sreq.on_token is not None:
                    sreq.on_token(sreq, toks, piece)

    def _write_reqlog(self, sreq: StreamingRequest) -> None:
        """One JSONL line per terminal request (detokenizer thread)."""
        uid = sreq._req.uid if sreq._req is not None else None
        rec = {"uid": uid,
               "error": sreq.error,
               "n_prompt": (len(sreq._req.prompt)
                            if sreq._req is not None else None),
               "n_tokens": len(sreq._req.out_tokens)
               if sreq._req is not None else 0,
               "ttft_s": sreq.ttft_s,
               "lifecycle": sreq.lifecycle(),
               "deltas": sreq.lifecycle_deltas()}
        line = json.dumps(rec) + "\n"
        with self._reqlog_lock:
            self._reqlog.write(line)
            self._reqlog.flush()

    # ---- lifecycle ----
    def close(self, timeout: Optional[float] = 60.0) -> None:
        """Drain in-flight work, then stop both threads."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._sched.join(timeout)
        self._detok.join(timeout)
        if self._reqlog is not None:
            with self._reqlog_lock:
                self._reqlog.close()

    def __enter__(self) -> "Orchestrator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
