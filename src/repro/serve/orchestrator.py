"""Async serving orchestrator over the three-stage engine API.

The engine (:mod:`repro.serve.engine`) is a synchronous slot machine:
``add_requests`` prefills+inserts, ``step`` generates one round for every
active slot.  This module wraps it JetStream-style with the HOST-side
concerns a real serving deployment has, overlapped with device compute on
background threads:

* a **submission queue** with backpressure — a bounded semaphore caps the
  number of requests in flight (queued + decoding); ``submit`` blocks up
  to an admission timeout and returns ``False`` instead of growing the
  queue without bound;
* a **scheduler thread** that drains submissions, groups compatible
  prompts into one bucketed-length prefill batch (``engine.add_requests``
  right-pads to a shared power-of-two bucket), runs the free-slot decode
  loop, requeues pool-dry evictions at the front of the line, and retires
  finished slots;
* a **detokenizer thread** that turns emitted token batches into text and
  fires per-token streaming callbacks, so Python-side string work never
  blocks the next ``generate`` dispatch.

Robustness (the chaos-hardened lifecycle; ``tests/test_chaos.py``):

* **every submitted request reaches a terminal state.**  ``_finish`` is
  idempotent (``_terminal`` flag under a lock), so deadline expiry,
  cancellation, loop crashes and normal completion can race without a
  double release or a stranded waiter;
* **deadlines + cancellation** — ``StreamingRequest.deadline_s`` (or the
  config-wide ``deadline_s``) expires a request relative to its submit
  stamp with terminal ``error="deadline"``; ``StreamingRequest.cancel()``
  is honored mid-decode with ``error="cancelled"``.  Both paths abort the
  engine side first (slot + pages reclaimed) on the scheduler thread;
* **crash containment** — a scheduler- or detokenizer-loop death fails
  every queued and in-flight request with an error, reclaims engine
  slots/pages, flips ``healthy`` to False (``submit`` then raises), and
  records the first worker exception, which ``__exit__`` re-raises and
  ``health()`` reports;
* a **watchdog thread** (``watchdog_s``) fails in-flight requests when
  the scheduler makes no progress for that long with work in flight —
  a stuck ``generate`` round degrades to fast errors instead of hangs;
* ``close`` raises on leaked (still-alive) worker threads instead of
  silently returning, and finishes any stragglers once both loops are
  down.

Fault injection (:mod:`repro.serve.faults`) hooks the scheduler tick,
tokenize and detokenize paths here (``sched_crash`` / ``tokenize_crash``
/ ``detok_crash``); the injector is shared with the engine
(``engine.faults``).  All hooks are ``is not None`` checks — disabled
costs nothing.

Tokenisation is pluggable (``tokenize``/``detokenize`` callables); the
default is a byte-level codec clipped to the model vocab, which is enough
for the synthetic-data models this repo trains.  Timing is recorded
host-side per emission (`submit`/first-token/finish ``perf_counter``
stamps — monotonic and comparable across threads), so the serving
benchmark can derive TTFT and inter-token latency percentiles without
touching the engine.

Telemetry rides the engine's :class:`repro.obs.MetricsRegistry` under the
``orch.`` prefix (``orch.submitted`` / ``finished`` / ``rejected`` /
``admission_timeouts`` / ``cancelled`` / ``deadline_expired`` /
``watchdog_fired`` / ``loop_crashes`` counters, ``orch.queue_depth``
gauge) and the engine's tracer: scheduler-loop segments get host spans
(``orch.pull``, ``orch.admit``, ``orch.step``, ``orch.retire``,
``orch.reap``, ``orch.idle``) and the detokenizer thread gets
``cat="detok"`` spans, which the stage-breakdown report counts as
concurrent rather than wall-clock.

Threading contract: the engine is only ever touched from the scheduler
thread; ``submit``/``wait``/``cancel``/``health`` are safe from any
thread.  Callbacks run on the detokenizer thread and must not call back
into the orchestrator (except ``submit``).
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from ..obs import StatsView
from .engine import Request, ServingEngine

__all__ = ["OrchestratorConfig", "StreamingRequest", "Orchestrator"]


@dataclasses.dataclass
class OrchestratorConfig:
    """Host-side serving knobs (the device-side ones live in ServeConfig).

    max_queue: backpressure cap on requests in flight (queued + active).
    admission_timeout_s: default ``submit`` blocking time once the queue
        is full; ``submit`` returns False on expiry instead of enqueueing.
    batch_window_s: how long the scheduler lingers after the first
        pending prompt to coalesce more arrivals into one bucketed
        prefill batch (0 = admit immediately).
    poll_interval_s: scheduler sleep when there is nothing to do.
    detokenize: decode emitted tokens to text on the detokenizer thread
        (False streams token ids only; text fields stay empty).
    deadline_s: default per-request deadline, measured from the submit
        stamp; on expiry the request terminates with ``error="deadline"``
        and its slot + pages are reclaimed.  A request's own
        ``deadline_s`` overrides this; None disables.
    watchdog_s: arm a watchdog thread that fails all in-flight requests
        (``error`` mentioning the watchdog, orchestrator marked
        unhealthy) when the scheduler completes no iteration for this
        long while work is in flight.  None disables.
    ttft_slo_s / itl_slo_s: latency SLO thresholds.  When set, every
        finished request's TTFT (and every inter-token gap) is checked
        against them and ``orch.slo.ttft_violations`` /
        ``orch.slo.itl_violations`` counters tick next to the matching
        ``*_total`` denominators.
    request_log: path of a JSONL file appended one line per terminal
        request (finished or rejected): uid, token count, error, TTFT
        and the full lifecycle decomposition (queue wait / prefill /
        insert / decode seconds from the engine's per-request stamps).
    """
    max_queue: int = 64
    admission_timeout_s: float = float("inf")
    batch_window_s: float = 0.0
    poll_interval_s: float = 0.001
    detokenize: bool = True
    deadline_s: Optional[float] = None
    watchdog_s: Optional[float] = None
    ttft_slo_s: Optional[float] = None
    itl_slo_s: Optional[float] = None
    request_log: Optional[str] = None


@dataclasses.dataclass(eq=False)
class StreamingRequest:
    """One streaming generation request.

    ``prompt`` may be text (tokenized host-side) or a token-id sequence.
    ``on_token(sreq, token_ids, text_piece)`` fires on the detokenizer
    thread once per emission batch — batches hold >1 token under
    speculative decoding because accepted drafts commit together.
    ``deadline_s`` (submit-relative) and :meth:`cancel` terminate the
    stream early with ``error="deadline"`` / ``"cancelled"``.
    """
    prompt: Union[str, Sequence[int]]
    max_new: int = 32
    temperature: Optional[float] = None   # None inherits ServeConfig's
    on_token: Optional[Callable[["StreamingRequest", List[int], str], None]] = None
    deadline_s: Optional[float] = None    # None inherits the config's

    # outputs / telemetry (filled in by the orchestrator)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    out_text: str = ""
    error: Optional[str] = None
    submit_t: float = 0.0
    token_t: List[float] = dataclasses.field(default_factory=list)
    finish_t: float = 0.0
    _req: Optional[Request] = dataclasses.field(default=None, repr=False)
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)
    _cancel: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)
    # terminal-once flag; only _finish flips it (under the orchestrator's
    # terminal lock), making every terminal path idempotent
    _terminal: bool = dataclasses.field(default=False, repr=False)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the stream finishes; True if it did."""
        return self._done.wait(timeout)

    def cancel(self) -> None:
        """Request cooperative cancellation: the scheduler aborts the
        stream at its next tick (terminal ``error="cancelled"``, slot
        and pages reclaimed).  Safe from any thread, no-op once the
        stream is already terminal."""
        self._cancel.set()

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit-to-first-token latency, once at least one token landed."""
        return self.token_t[0] - self.submit_t if self.token_t else None

    def itl_s(self) -> List[float]:
        """Inter-token gaps (speculative batches share one stamp → 0s)."""
        return [b - a for a, b in zip(self.token_t, self.token_t[1:])]

    def lifecycle(self) -> Dict[str, float]:
        """The engine's per-request ``perf_counter`` stamps, in lifecycle
        order (submit → admit → prefill_done → insert_done → first_token
        → finish).  Rejected requests carry only submit + finish; keys a
        request never reached are absent.  Requests that never got an
        engine-side Request (e.g. failed before tokenization) still
        carry the orchestrator's own submit/finish stamps — every
        terminal path has both."""
        timing = dict(self._req.timing) if self._req is not None else {}
        if self.submit_t:
            timing.setdefault("submit", self.submit_t)
        if self.finish_t:
            timing.setdefault("finish", self.finish_t)
        order = ("submit", "admit", "prefill_done", "insert_done",
                 "first_token", "finish")
        return {k: timing[k] for k in order if k in timing}

    def lifecycle_deltas(self) -> Dict[str, float]:
        """TTFT decomposition in seconds relative to submit: queue wait
        (submit→admit), prefill (admit→prefill_done), insert
        (prefill_done→insert_done plus first-token sampling up to
        first_token), decode (first_token→finish), total."""
        t = self.lifecycle()
        out: Dict[str, float] = {}
        if "admit" in t:
            out["queue_wait_s"] = t["admit"] - t["submit"]
        if "prefill_done" in t and "admit" in t:
            out["prefill_s"] = t["prefill_done"] - t["admit"]
        if "insert_done" in t and "prefill_done" in t:
            out["insert_s"] = t["insert_done"] - t["prefill_done"]
        if "first_token" in t:
            out["ttft_s"] = t["first_token"] - t["submit"]
        if "finish" in t:
            out["total_s"] = t["finish"] - t["submit"]
            if "first_token" in t:
                out["decode_s"] = t["finish"] - t["first_token"]
        return out


def _default_tokenize(vocab: int) -> Callable[[str], List[int]]:
    def tok(text: str) -> List[int]:
        return [min(b, vocab - 1) for b in text.encode("utf-8")]
    return tok


def _default_detokenize(vocab: int) -> Callable[[List[int]], str]:
    del vocab
    def detok(toks: List[int]) -> str:
        return bytes(t % 256 for t in toks).decode("utf-8", errors="replace")
    return detok


class Orchestrator:
    """Threaded request orchestrator over a ServingEngine.

    Usage::

        with Orchestrator(engine) as orch:
            sreq = StreamingRequest("hello", max_new=16,
                                    on_token=lambda r, ids, s: print(s))
            assert orch.submit(sreq)
            sreq.wait()

    ``__exit__`` re-raises the first worker-thread exception (as the
    cause of a RuntimeError) if a loop crashed during the block."""

    def __init__(self, engine: ServingEngine,
                 ocfg: OrchestratorConfig = OrchestratorConfig(), *,
                 tokenize: Optional[Callable[[str], List[int]]] = None,
                 detokenize: Optional[Callable[[List[int]], str]] = None):
        if ocfg.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {ocfg.max_queue}")
        self.engine = engine
        self.ocfg = ocfg
        vocab = engine.cfg.vocab
        self.tokenize = tokenize or _default_tokenize(vocab)
        self.detokenize = detokenize or _default_detokenize(vocab)
        # fault-injection hooks for the orchestrator's own sites
        # (sched/tokenize/detok) share the engine's injector
        self.faults = getattr(engine, "faults", None)

        self._slots = threading.BoundedSemaphore(ocfg.max_queue)
        self._submitted: "queue.Queue[StreamingRequest]" = queue.Queue()
        self._stream_q: "queue.Queue[tuple]" = queue.Queue()
        self._by_req: Dict[int, StreamingRequest] = {}  # id(Request) -> sreq
        self._pending: deque = deque()     # scheduler thread only
        self._closed = False
        self._uid = 0
        self._stop = threading.Event()
        # ---- robustness state ----
        self._healthy = True
        self._fail_reason: Optional[str] = None
        self._worker_exc: Optional[BaseException] = None
        self._term_lock = threading.Lock()   # _terminal + _worker_exc
        self._detok_gate = threading.Lock()  # _detok_dead + "done" enqueue
        self._detok_dead = False
        self._beat = time.perf_counter()     # scheduler progress heartbeat
        self.tracer = engine.tracer
        self.metrics = engine.metrics
        self.stats = StatsView(self.metrics, prefix="orch.")
        self.stats.bind_counters("submitted", "finished", "rejected",
                                 "admission_timeouts", "cancelled",
                                 "deadline_expired", "watchdog_fired",
                                 "loop_crashes")
        self._queue_depth = self.metrics.gauge("orch.queue_depth")
        # lifecycle latency distributions + SLO accounting (scheduler
        # thread only; Histogram.observe is locked anyway)
        self._h_ttft = self.metrics.histogram("orch.ttft_s")
        self._h_itl = self.metrics.histogram("orch.itl_s")
        self._h_qwait = self.metrics.histogram("orch.queue_wait_s")
        self._slo = {k: self.metrics.counter(f"orch.slo.{k}")
                     for k in ("ttft_total", "ttft_violations",
                               "itl_total", "itl_violations")}
        self._reqlog = (open(ocfg.request_log, "a")
                        if ocfg.request_log else None)
        self._reqlog_lock = threading.Lock()

        engine.on_emit = self._on_emit       # runs on the scheduler thread
        self._sched = threading.Thread(target=self._scheduler_loop,
                                       name="orch-scheduler", daemon=True)
        self._detok = threading.Thread(target=self._detok_loop,
                                       name="orch-detok", daemon=True)
        self._wd: Optional[threading.Thread] = None
        self._wd_stop = threading.Event()
        self._sched.start()
        self._detok.start()
        if ocfg.watchdog_s is not None:
            self._wd = threading.Thread(target=self._watchdog_loop,
                                        name="orch-watchdog", daemon=True)
            self._wd.start()

    # ---- submission side (any thread) ----
    def submit(self, sreq: StreamingRequest,
               timeout: Optional[float] = None) -> bool:
        """Enqueue a request; False if backpressure held past ``timeout``
        (default: the config admission timeout).  Raises once the
        orchestrator is closed or unhealthy (a worker loop died)."""
        if self._closed:
            raise RuntimeError("orchestrator is closed")
        if not self._healthy:
            raise RuntimeError(
                f"orchestrator is unhealthy: {self._fail_reason}")
        if timeout is None:
            timeout = self.ocfg.admission_timeout_s
        blocking = timeout > 0
        if not self._slots.acquire(
                blocking,
                None if timeout == float("inf") or not blocking else timeout):
            self.stats["admission_timeouts"] += 1
            return False
        sreq.submit_t = time.perf_counter()
        self.stats["submitted"] += 1
        self._submitted.put(sreq)
        self._queue_depth.set(self._submitted.qsize())
        return True

    # ---- health (any thread) ----
    @property
    def healthy(self) -> bool:
        """False once a worker loop died or the watchdog fired."""
        return self._healthy

    @property
    def worker_exc(self) -> Optional[BaseException]:
        """First exception that killed a worker loop (None = none yet)."""
        return self._worker_exc

    def health(self) -> Dict[str, Any]:
        """Point-in-time health snapshot (thread-safe, JSON-friendly):
        liveness flags, the first failure, thread liveness, in-flight
        depth, engine slot/page occupancy and the robustness counters
        (``orch.*`` / ``faults.*`` / ``guard.*`` / stage retries).
        Surfaced by ``launch/serve.py --health``."""
        c = self.metrics.snapshot()["counters"]
        keep = ("orch.", "faults.", "guard.")
        threads = {t.name: t.is_alive()
                   for t in (self._sched, self._detok, self._wd)
                   if t is not None}
        alloc = getattr(self.engine, "allocator", None)
        return {
            "healthy": self._healthy,
            "closed": self._closed,
            "error": self._fail_reason,
            "worker_exc": (repr(self._worker_exc)
                           if self._worker_exc is not None else None),
            "threads": threads,
            "in_flight": len(self._by_req) + self._submitted.qsize(),
            "engine": {
                "free_slots": self.engine.free_slots(),
                "live_pages": (alloc.live_pages
                               if alloc is not None else None)},
            "counters": {k: v for k, v in sorted(c.items())
                         if k.startswith(keep)
                         or k == "stage.retries"
                         or k == "stage.retry_exhausted"},
        }

    # ---- scheduler thread ----
    def _on_emit(self, req: Request, toks: List[int]) -> None:
        sreq = self._by_req.get(id(req))
        if sreq is None:
            return
        now = time.perf_counter()
        sreq.token_t.extend([now] * len(toks))
        self._stream_q.put(("toks", sreq, list(toks)))

    def _finish(self, sreq: StreamingRequest, error: Optional[str] = None):
        """Terminal transition for one stream.  Idempotent — the first
        caller wins (normal retire, deadline/cancel reap, crash
        containment and close-time backstop may race); every path ends
        with ``_done`` set and the backpressure slot released."""
        with self._term_lock:
            if sreq._terminal:
                return
            sreq._terminal = True
        sreq.error = error
        sreq.finish_t = time.perf_counter()
        if sreq._req is not None:
            # rejects the orchestrator filters itself never reach the
            # engine's stamping paths; backfill the terminal stamps so
            # every terminal request has submit+finish
            sreq._req.timing.setdefault("submit", sreq.submit_t)
            sreq._req.timing.setdefault("finish", sreq.finish_t)
        self._observe_slo(sreq)
        self.stats["rejected" if error else "finished"] += 1
        with self._detok_gate:
            if self._detok_dead:
                # the detokenizer is gone: resolve the waiter directly
                # instead of enqueueing for a dead consumer
                sreq._done.set()
            else:
                self._stream_q.put(("done", sreq))
        self._slots.release()

    def _observe_slo(self, sreq: StreamingRequest) -> None:
        """Latency histograms + SLO violation counters for one terminal
        request."""
        d = sreq.lifecycle_deltas()
        if "queue_wait_s" in d:
            self._h_qwait.observe(d["queue_wait_s"])
        ttft = sreq.ttft_s
        if ttft is not None:
            self._h_ttft.observe(ttft)
            if self.ocfg.ttft_slo_s is not None:
                self._slo["ttft_total"].inc()
                if ttft > self.ocfg.ttft_slo_s:
                    self._slo["ttft_violations"].inc()
        for gap in sreq.itl_s():
            self._h_itl.observe(gap)
            if self.ocfg.itl_slo_s is not None:
                self._slo["itl_total"].inc()
                if gap > self.ocfg.itl_slo_s:
                    self._slo["itl_violations"].inc()

    def _record_worker_exc(self, exc: BaseException) -> None:
        with self._term_lock:
            if self._worker_exc is None:
                self._worker_exc = exc

    def _contain(self, reason: str, *, engine_safe: bool) -> None:
        """Crash containment: mark the orchestrator unhealthy and finish
        EVERY queued or in-flight request with ``reason`` — no stream is
        ever stranded behind a dead loop.  ``engine_safe`` means we are
        on the scheduler thread (the only thread allowed to touch the
        engine), so slots/pages are reclaimed too; other threads leave
        engine cleanup to the scheduler, which runs containment again on
        its next iteration when it observes ``healthy == False``."""
        self._healthy = False
        if self._fail_reason is None:
            self._fail_reason = reason
        if engine_safe:
            eng = self.engine
            try:
                for r in list(eng._evicted):
                    eng.abort(r, error=reason)
                for r in list(eng.slot_req):
                    if r is not None:
                        eng.abort(r, error=reason)
            except Exception:
                # a corrupted engine must not block failing the streams
                pass
        while True:
            try:
                sreq = self._submitted.get_nowait()
            except queue.Empty:
                break
            self._finish(sreq, error=reason)
        for sreq in list(self._by_req.values()):
            self._finish(sreq, error=sreq.error or reason)
        if engine_safe:
            self._by_req.clear()
            self._pending.clear()
        self._queue_depth.set(0)

    def _scheduler_loop(self) -> None:
        try:
            self._scheduler_body()
        except BaseException as e:  # containment must see everything
            self._record_worker_exc(e)
            self.stats["loop_crashes"] += 1
            self._contain(f"scheduler loop crashed: {e!r}",
                          engine_safe=True)
        finally:
            # graceful exit and crash exit both stop the detokenizer
            self._stream_q.put(("stop",))

    def _scheduler_body(self) -> None:
        eng, ocfg, tracer = self.engine, self.ocfg, self.tracer
        pending = self._pending
        while True:
            self._beat = time.perf_counter()
            if not self._healthy:
                # another thread (watchdog / detokenizer) initiated
                # containment; do the engine-side half here and exit
                self._contain(self._fail_reason or "orchestrator unhealthy",
                              engine_safe=True)
                return
            if self.faults is not None:
                self.faults.on_sched()
            # pull new submissions; filter out the never-admissible
            fresh = False
            with tracer.span("orch.pull"):
                while True:
                    try:
                        sreq = self._submitted.get_nowait()
                    except queue.Empty:
                        break
                    try:
                        sreq._req = self._to_engine_request(sreq)
                    except BaseException as e:
                        # the popped request is in neither queue nor
                        # _by_req — finish it before containment runs,
                        # or it would be the one stream left stranded
                        self._finish(sreq, error=f"tokenize failed: {e!r}")
                        raise
                    reject = eng._reject_reason(sreq._req)
                    if reject is not None:
                        self._finish(sreq, error=reject)
                        continue
                    self._by_req[id(sreq._req)] = sreq
                    pending.append(sreq)
                    fresh = True
                # pool-dry evictions resume at the head of the line
                if eng._evicted:
                    evicted, eng._evicted = eng._evicted, []
                    for r in reversed(evicted):
                        pending.appendleft(self._by_req[id(r)])
                self._queue_depth.set(len(pending))
            # cancellations + expired deadlines before spending a tick
            self._reap()
            if fresh and ocfg.batch_window_s > 0 and eng.free_slots():
                with tracer.span("orch.idle", kind="batch_window"):
                    time.sleep(ocfg.batch_window_s)   # coalesce one batch
                continue
            # bucketed admission: one shared-bucket prefill per batch
            if pending and eng.free_slots():
                with tracer.span("orch.admit", n=len(pending)):
                    batch = [pending.popleft() for _ in
                             range(min(len(pending), eng.free_slots()))]
                    ok = eng.add_requests([s._req for s in batch])
                    failed = [s for s, admitted in zip(batch, ok)
                              if not admitted]
                    for s in reversed(failed):   # infeasible right now:
                        pending.appendleft(s)    # retry in FIFO order
                self._queue_depth.set(len(pending))
            active = any(r is not None for r in eng.slot_req)
            if active:
                with tracer.span("orch.step"):
                    eng.step()
            # retire finished requests (admission can finish prompt-only
            # requests too, so scan the full map)
            with tracer.span("orch.retire"):
                done_ids = [rid for rid, s in self._by_req.items()
                            if s._req.done and s not in pending]
                for rid in done_ids:
                    s = self._by_req.pop(rid)
                    self._finish(s, error=s._req.error)
            if self._stop.is_set() and not pending and not active \
                    and self._submitted.empty() and not eng._evicted:
                return
            if not active and not pending:
                with tracer.span("orch.idle", kind="poll"):
                    time.sleep(ocfg.poll_interval_s)

    def _reap(self) -> None:
        """Terminate cancelled and deadline-expired requests (scheduler
        thread): abort the engine side first — slot and pages reclaimed —
        then finish with the terminal error."""
        now = time.perf_counter()
        doomed = []
        for sreq in list(self._by_req.values()):
            if sreq._terminal:
                continue
            if sreq._cancel.is_set():
                doomed.append((sreq, "cancelled"))
                continue
            dl = (sreq.deadline_s if sreq.deadline_s is not None
                  else self.ocfg.deadline_s)
            if dl is not None and now - sreq.submit_t > dl:
                doomed.append((sreq, "deadline"))
        if not doomed:
            return
        with self.tracer.span("orch.reap", n=len(doomed)):
            for sreq, err in doomed:
                if sreq in self._pending:
                    self._pending.remove(sreq)
                self._by_req.pop(id(sreq._req), None)
                self.engine.abort(sreq._req, error=err)
                self._finish(sreq, error=err)
                self.stats["cancelled" if err == "cancelled"
                           else "deadline_expired"] += 1

    def _to_engine_request(self, sreq: StreamingRequest) -> Request:
        if self.faults is not None:
            self.faults.on_tokenize()
        toks = (self.tokenize(sreq.prompt)
                if isinstance(sreq.prompt, str) else
                [int(t) for t in sreq.prompt])
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(toks, np.int32),
                      max_new=sreq.max_new, temperature=sreq.temperature)
        # the engine's lifecycle stamps start from the true submission
        # time, not the scheduler pull time, so queue wait is end-to-end
        req.timing["submit"] = sreq.submit_t
        return req

    # ---- watchdog thread ----
    def _watchdog_loop(self) -> None:
        wd = self.ocfg.watchdog_s
        tick = max(min(wd / 4.0, 0.25), 0.005)
        while not self._wd_stop.wait(tick):
            busy = bool(self._by_req) or not self._submitted.empty()
            stale = time.perf_counter() - self._beat
            if busy and stale > wd and self._healthy:
                self.stats["watchdog_fired"] += 1
                msg = (f"watchdog: scheduler made no progress for "
                       f"{stale:.2f}s (> {wd}s) with work in flight")
                self._record_worker_exc(RuntimeError(msg))
                # fail the waiters NOW; the scheduler (if it ever
                # recovers) sees unhealthy and reclaims the engine side
                self._contain(msg, engine_safe=False)
                return

    # ---- detokenizer thread ----
    def _detok_loop(self) -> None:
        try:
            self._detok_body()
        except BaseException as e:
            self._record_worker_exc(e)
            self.stats["loop_crashes"] += 1
            with self._detok_gate:
                # flag first, then drain: under the gate no "done" can be
                # enqueued concurrently, and every later _finish resolves
                # its waiter directly
                self._detok_dead = True
                while True:
                    try:
                        item = self._stream_q.get_nowait()
                    except queue.Empty:
                        break
                    if item[0] == "done":
                        item[1]._done.set()
            self._contain(f"detokenizer loop crashed: {e!r}",
                          engine_safe=False)

    def _detok_body(self) -> None:
        while True:
            item = self._stream_q.get()
            if item[0] == "stop":
                return
            if item[0] == "done":
                # log BEFORE _done.set(): close() joins this thread, so a
                # waiter that saw done=True is guaranteed a flushed line
                if self._reqlog is not None:
                    self._write_reqlog(item[1])
                item[1]._done.set()
                continue
            _, sreq, toks = item
            if self.faults is not None:
                self.faults.on_detok()
            # cat="detok" → the breakdown report counts this thread's work
            # as concurrent with the scheduler, not extra wall time
            with self.tracer.span("orch.detok", cat="detok", n=len(toks)):
                sreq.out_tokens.extend(toks)
                piece = ""
                if self.ocfg.detokenize:
                    piece = self.detokenize(toks)
                    sreq.out_text += piece
                if sreq.on_token is not None:
                    sreq.on_token(sreq, toks, piece)

    def _write_reqlog(self, sreq: StreamingRequest) -> None:
        """One JSONL line per terminal request (detokenizer thread)."""
        uid = sreq._req.uid if sreq._req is not None else None
        rec = {"uid": uid,
               "error": sreq.error,
               "n_prompt": (len(sreq._req.prompt)
                            if sreq._req is not None else None),
               "n_tokens": len(sreq._req.out_tokens)
               if sreq._req is not None else 0,
               "ttft_s": sreq.ttft_s,
               "lifecycle": sreq.lifecycle(),
               "deltas": sreq.lifecycle_deltas()}
        line = json.dumps(rec) + "\n"
        with self._reqlog_lock:
            self._reqlog.write(line)
            self._reqlog.flush()

    # ---- lifecycle ----
    def close(self, timeout: Optional[float] = 60.0) -> None:
        """Drain in-flight work, then stop all worker threads.

        Raises RuntimeError if a worker thread is still alive after
        ``timeout`` — a leaked thread means a stuck scheduler or
        detokenizer, and silently returning used to mask exactly that.
        Once both loops are down, any straggler requests (submitted
        around the stop, or stranded by a crash) are finished with an
        error so no waiter hangs."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._sched.join(timeout)
        self._detok.join(timeout)
        self._wd_stop.set()
        if self._wd is not None:
            self._wd.join(timeout)
        leaked = [t.name for t in (self._sched, self._detok)
                  if t.is_alive()]
        if not leaked:
            with self._detok_gate:
                self._detok_dead = True   # finish() resolves waiters now
            err = self._fail_reason or "orchestrator closed"
            while True:
                try:
                    sreq = self._submitted.get_nowait()
                except queue.Empty:
                    break
                self._finish(sreq, error=err)
            for sreq in list(self._by_req.values()):
                self._finish(sreq, error=sreq.error or err)
            self._by_req.clear()
        if self._reqlog is not None:
            with self._reqlog_lock:
                self._reqlog.close()
        if leaked:
            raise RuntimeError(
                f"orchestrator close(timeout={timeout}) leaked threads: "
                f"{leaked} — scheduler or detokenizer failed to stop")

    def __enter__(self) -> "Orchestrator":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
        if exc_type is None and self._worker_exc is not None:
            raise RuntimeError(
                f"orchestrator worker crashed: {self._fail_reason}"
            ) from self._worker_exc
