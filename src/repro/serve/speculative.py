"""Transprecision self-speculative decoding: posit8 draft, target verify.

The paper's TALU switches precision at runtime on ONE datapath; the
serving-side analogue is to run the SAME weights twice per chunk at two
precisions: up to ``gamma`` cheap autoregressive *draft* steps under a
derived low-precision policy (posit8 weight compute + posit8 KV ring by
default, ``core.transprecision.draft_policy``), then ONE *verify* pass
under the full-precision target policy that scores all chunk positions at
once (the engine API's ``verify`` stage).  Draft tokens that match the
target's greedy choice commit; the first mismatch yields the target's own
token as a free bonus, and the speculatively written K/V rows past the
commit point are **rolled back**:

* ring layout — rewind the per-slot ``pos`` vector and scrub the
  rolled-back code/scale rows to their init values.  Scatter form: only
  the fixed-size window of rows the round wrote is touched — O(B·gamma)
  rows per round, independent of ``max_len``
  (``engine_api.rollback_ring_cache``);
* paged layout — truncate the slot's page list to the committed length,
  return orphaned pages through the refcounted allocator, and scrub the
  rolled-back pool rows.

Because the verify pass evaluates the exact decode-path math per token
(``chunk_decode_attention`` masks rejected rows to exact zeros), greedy
speculative decode emits token-for-token the same stream as baseline
greedy decode — the draft precision only moves the ACCEPTANCE RATE, i.e.
how many target-model steps each emitted token costs, never the output.

Near the cache cap the chunk *shrinks dynamically*: a round's chunk is
``T = min(gamma + 1, min_i(max_len - pos_i))`` over the active slots, so
slots decode all the way to ``max_len - 1`` and cap-truncated streams are
token-identical to baseline (admission needs one extra row of headroom:
prompts longer than ``max_len - 2`` are rejected, vs baseline's
``max_len - 1``).

Draft-cache lifecycle: the draft ring mirrors the committed prefix.  When
every draft in a round is accepted the draft cache is one committed row
short (the last draft token was never fed through the draft model); that
slot's next round spends its first draft step catching up (output
discarded) and proposes one fewer token.  Lag never exceeds one row.

Stream identity is bit-exact on the CPU/reference backend (what CI pins).
On accelerators the baseline decode reads through the fused Pallas
kernels while the verify chunk reads through gather+decode XLA attention
— different summation orders, so near-tied logits could in principle
argmax differently until the fused chunk-verify kernel (ROADMAP) lands.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.transprecision import BF16, TCPolicy, draft_policy
from ..models import lm
from .engine import Request, ServeConfig, ServingEngine
from .engine_api import (TransprecisionEngine, rollback_paged_cache,
                         rollback_ring_cache)
from .paged import pages_for

__all__ = ["SpeculativeEngine", "rollback_ring_cache",
           "rollback_paged_cache"]


class SpeculativeEngine(ServingEngine):
    """Continuous-batching engine with self-speculative greedy decode.

    Per round (one ``step()``): up to gamma lockstep draft ``generate``
    steps on a draft-policy engine, one ``verify`` chunk on the target
    engine, per-slot acceptance, KV rollback.  Greedy-only: requests
    whose resolved temperature is > 0 are rejected at admission
    (acceptance compares argmax streams; stochastic acceptance is a
    follow-on).
    """

    def __init__(self, cfg: lm.ModelCfg, params, scfg: ServeConfig,
                 policy: TCPolicy = BF16, *, gamma: int = 4,
                 draft_weights_fmt: str = "posit8_2",
                 draft_kv_format: str = "posit8", tracer=None,
                 faults=None, retry=None, guard=None):
        if gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {gamma}")
        if guard:
            raise ValueError(
                "the numeric guard is a base-engine decode-round policy; "
                "speculative verify-round quarantine is a follow-on "
                "(pass guard=None)")
        if any(bt != "attn" for bt in cfg.block_types) or cfg.window \
                or cfg.family in ("moe", "audio"):
            raise ValueError(
                "speculative decoding needs a decoder-only attention "
                "stack without MoE or sliding windows (rollback is a row "
                f"rewind); {cfg.name} is not one")
        super().__init__(cfg, params, scfg, policy, tracer=tracer,
                         faults=faults, retry=retry)
        self.gamma = gamma
        self._T = gamma + 1                     # max verify chunk length
        if scfg.max_len <= 2:
            raise ValueError(f"max_len {scfg.max_len} leaves no room for "
                             "a verify chunk")
        self.draft = draft_policy(self.policy, weights_fmt=draft_weights_fmt,
                                  kv_format=draft_kv_format)
        b, L = scfg.max_batch, scfg.max_len
        # the draft runs its own three-stage engine over a dense ring; it
        # shares the driver's tracer + registry so draft stage time shows
        # up under "draft.generate" etc., separate from the target stages
        self.draft_engine = TransprecisionEngine(
            cfg, self.draft, b, L, tracer=self.tracer,
            metrics=self.metrics, stage_prefix="draft.",
            faults=self.faults, retry=self.retry)
        self.draft_cache = self.draft_engine.init_decode_state()
        self.draft_pos = np.zeros(b, np.int64)  # committed draft rows/slot
        # committed token the draft cache is missing (all-accepted rounds
        # leave the draft one row behind); None = in sync
        self._lag_tok: List[Optional[int]] = [None] * b

        self.stats.bind_counters("spec_rounds", "draft_steps",
                                 "drafts_proposed", "drafts_accepted")
        # first-class speculative distributions: per-round verify chunk
        # length, accepted drafts per slot-round, and KV rows rolled back
        # per slot-round (the cost of a rejection)
        self._h_chunk = self.metrics.histogram("spec.chunk_T",
                                               lo=1.0, hi=1e3, ratio=1.25)
        self._h_accept = self.metrics.histogram("spec.accepted_per_round",
                                                lo=1.0, hi=1e3, ratio=1.25)
        self._h_rollback = self.metrics.histogram("spec.rollback_rows",
                                                  lo=1.0, hi=1e3,
                                                  ratio=1.25)
        # the draft ring is real HBM: re-report the footprint including it
        self.stats["kv_cache_bytes"] = self.kv_cache_bytes()

    # ---- cache footprint (target cache + the dense draft ring) ----
    def _draft_kv_bytes(self) -> int:
        """The draft ring's reserved bytes (a dense per-slot max_len ring
        at draft precision — always fully reserved, never paged).  0 while
        the base __init__ runs, before the draft cache exists."""
        draft_cache = getattr(self, "draft_cache", None)
        if draft_cache is None:
            return 0
        return self._kv_bytes(cache=draft_cache)

    def kv_cache_bytes(self) -> int:
        return super().kv_cache_bytes() + self._draft_kv_bytes()

    def kv_cache_live_bytes(self) -> int:
        return super().kv_cache_live_bytes() + self._draft_kv_bytes()

    def kv_cache_peak_live_bytes(self) -> int:
        return super().kv_cache_peak_live_bytes() + self._draft_kv_bytes()

    # ---- admission ----
    def _reject_reason(self, req: Request) -> Optional[str]:
        r = super()._reject_reason(req)
        if r is not None:
            return r
        if len(self._admission_tokens(req)) > self.scfg.max_len - 2:
            return (f"prompt length {len(req.prompt)} > max_len - 2 = "
                    f"{self.scfg.max_len - 2}: no row of verify-chunk "
                    "headroom")
        if self._req_temp(req) > 0:
            return ("speculative decoding is greedy-only; set "
                    "Request.temperature=0 (or serve through the baseline "
                    "engine)")
        return None

    def _worst_pages(self, req: Request) -> int:
        """Worst-case page demand including the verify chunk's transient
        rows: a round may write up to gamma+1 rows past the committed
        length before rolling back, so the reservation covers
        committed + T."""
        s = len(self._admission_tokens(req))
        remaining = max(req.max_new - len(req.out_tokens), 0)
        tokens = min(max(s + remaining, s + 1) + self._T,
                     self.scfg.max_len)
        return pages_for(tokens, self.allocator.page_size)

    def _free_request_slot(self, slot: int) -> None:
        super()._free_request_slot(slot)
        self.draft_pos[slot] = 0
        self._lag_tok[slot] = None

    def add_requests(self, reqs: List[Request]) -> List[bool]:
        # each admission needs its own draft prefill; route the batched
        # entry point through add_request (no bucketed batch prefill on
        # the speculative path yet)
        ok: List[bool] = []
        for r in reqs:
            admitted = self.add_request(r)
            ok.append(admitted)
            if not admitted:
                break
        ok.extend([False] * (len(reqs) - len(ok)))
        return ok

    def add_request(self, req: Request) -> bool:
        reject = self._reject_reason(req)
        if reject is not None:
            raise ValueError(f"{reject}; reject before admission")
        toks = np.asarray(self._admission_tokens(req))  # before _install
        if not all(ServingEngine.add_requests(self, [req])):
            return False
        slot = next((i for i, r in enumerate(self.slot_req) if r is req),
                    None)
        if slot is None:        # finished at admission (max_new<=1 / EOS)
            return True
        # draft-cache lifecycle: mirror the prompt into the draft ring so
        # round 1 drafts from the same committed prefix as the target
        n = len(toks)
        bucket = self.draft_engine.bucket_for(n)
        pad = np.zeros((1, bucket), np.int32)
        pad[0, :n] = toks
        dpfx = self.draft_engine.prefill(self.params, pad, [n])
        self.draft_cache = self.draft_engine.insert(dpfx, self.draft_cache,
                                                    slot)
        self.draft_pos[slot] = n
        self._lag_tok[slot] = None
        return True

    # ---- one speculative round for the whole batch ----
    def step(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        b = self.scfg.max_batch
        # dynamic chunk shrink at the cache cap: the round's chunk must
        # fit every active slot's remaining rows, so slots decode all the
        # way to max_len - 1 exactly like baseline (admission keeps
        # pos <= max_len - 2 while active, so T >= 2)
        T = min(self._T,
                int(min(self.scfg.max_len - self.slot_pos[i]
                        for i in active)))
        gamma = T - 1
        pre_pos = self.slot_pos.copy()          # committed rows per slot
        pre_draft = self.draft_pos.copy()

        self._h_chunk.observe(T)

        # ---- draft phase: gamma lockstep low-precision steps ----
        cur = np.zeros((b, 1), np.int32)
        proposals = np.zeros((b, gamma), np.int32)
        nprop = np.zeros(b, np.int64)
        catchup = np.zeros(b, bool)
        for i in active:
            if self._lag_tok[i] is not None:
                cur[i, 0] = self._lag_tok[i]
                catchup[i] = True
            else:
                cur[i, 0] = self.last_tok[i, 0]
        with self.tracer.span("spec.draft", cat="host"):
            for s in range(gamma):
                self.draft_cache["tok"] = jnp.asarray(cur)
                self.draft_cache, logits_d = self.draft_engine.generate(
                    self.params, self.draft_cache)
                toks = np.asarray(logits_d)[:, : self.cfg.vocab].argmax(-1)
                self.stats["draft_steps"] += 1
                for i in active:
                    if s == 0 and catchup[i]:
                        # catch-up: the output re-predicts a token we
                        # already committed; discard it and feed the real
                        # one next
                        cur[i, 0] = self.last_tok[i, 0]
                        continue
                    proposals[i, nprop[i]] = toks[i]
                    nprop[i] += 1
                    cur[i, 0] = toks[i]
        self.stats["drafts_proposed"] += int(nprop[active].sum())

        # ---- verify phase: one target-precision chunk pass ----
        chunk = np.zeros((b, T), np.int32)
        for i in active:
            chunk[i, 0] = self.last_tok[i, 0]
            chunk[i, 1:1 + nprop[i]] = proposals[i, : nprop[i]]
        if self.paged:
            self._grow_pages(active, lambda i: self.slot_pos[i] + T)
            active = [i for i in active if self.slot_req[i] is not None]
            if not active:
                return
        # page lists as of the verify write extent (rollback scrubs
        # against these, BEFORE truncation/free)
        old_pages = ([list(self.slot_pages[i].pages) for i in range(b)]
                     if self.paged else None)
        self.cache, logits_v = self.engine.verify(self.params, self.cache,
                                                  chunk)
        g = np.asarray(logits_v)[..., : self.cfg.vocab].argmax(-1)  # (B, T)
        self.stats["decode_steps"] += 1
        self.stats["spec_rounds"] += 1

        # ---- per-slot acceptance + commit ----
        with self.tracer.span("spec.accept", cat="host"):
            for i in active:
                req = self.slot_req[i]
                n = int(nprop[i])
                k = 0
                while k < n and proposals[i, k] == g[i, k]:
                    k += 1
                # emission budget: keep the stream identical to baseline
                # greedy, which stops at exactly max_new tokens and frees
                # the slot once pos reaches max_len - 1 (post-emission
                # check, so at least one token always lands)
                cap = max(int(self.scfg.max_len - 1 - pre_pos[i]), 1)
                k = min(k, req.max_new - len(req.out_tokens) - 1, cap - 1)
                emitted = [int(t) for t in proposals[i, :k]] + [int(g[i, k])]
                eos = self.scfg.eos_id
                if eos is not None and eos in emitted:
                    emitted = emitted[: emitted.index(eos) + 1]
                # emitted tokens are accepted drafts plus (unless an EOS
                # draft truncated the list first) one non-draft bonus token
                self.stats["drafts_accepted"] += min(len(emitted), k)
                self._h_accept.observe(min(len(emitted), k))
                self.last_tok[i, 0] = emitted[-1]
                self.slot_pos[i] = pre_pos[i] + len(emitted)
                self._emit(req, emitted)
                # draft sync: rows the draft holds for the committed prefix
                drafted_rows = pre_draft[i] + gamma
                self.draft_pos[i] = min(drafted_rows, self.slot_pos[i])
                lag = int(self.slot_pos[i] - self.draft_pos[i])
                self._lag_tok[i] = int(chunk[i, k]) if lag else None
                if (len(req.out_tokens) >= req.max_new
                        or (eos is not None and emitted[-1] == eos)
                        or self.slot_pos[i] >= self.scfg.max_len - 1):
                    req.done = True
                    self._free_request_slot(i)  # resets slot + draft state

        # ---- KV rollback: target cache ----
        new_pos = self.slot_pos.copy()          # post-free (0 for done/idle)
        with self.tracer.span("spec.rollback", cat="host"):
            for i in active:
                if self.slot_req[i] is not None:
                    self._h_rollback.observe(int(pre_pos[i]) + T
                                             - int(new_pos[i]))
            if self.paged:
                ps = self.allocator.page_size
                scrub = np.zeros(b * T, np.int64)  # padded w/ trash row 0
                nscrub = 0
                truncated = False
                for i in active:
                    if self.slot_req[i] is None:   # freed above: pages
                        continue                   # already in the pool
                    sp = self.slot_pages[i]
                    keep = pages_for(int(new_pos[i]), ps)
                    orphans = sp.pages[keep:]
                    for p in range(int(new_pos[i]), int(pre_pos[i]) + T):
                        scrub[nscrub] = old_pages[i][p // ps] * ps + p % ps
                        nscrub += 1
                    if orphans:
                        self.allocator.free(orphans)
                        del sp.pages[keep:]
                        self._table[i] = sp.table_row(self._pmax)
                        truncated = True
                if truncated:
                    self.cache["page_table"] = jnp.asarray(self._table)
                self.cache = self.engine.rollback_paged(self.cache, new_pos,
                                                        scrub)
            else:
                # scatter form: only the T rows this round wrote per slot.
                # Freed slots skip the scrub (their rows are rewritten
                # before any read on readmission); idle slots no-op.
                window_end = np.full(b, T, np.int64)
                scrub_from = window_end.copy()
                for i in active:
                    window_end[i] = pre_pos[i] + T
                    scrub_from[i] = (self.slot_pos[i]
                                     if self.slot_req[i] is not None
                                     else window_end[i])
                self.cache = self.engine.rollback_ring(
                    self.cache, new_pos, window_end, scrub_from, T)
            # ---- KV rollback: draft ring (always ring layout) ----
            d_end = np.full(b, gamma, np.int64)
            d_from = d_end.copy()
            for i in active:
                d_end[i] = pre_draft[i] + gamma
                d_from[i] = (self.draft_pos[i]
                             if self.slot_req[i] is not None
                             else d_end[i])
            self.draft_cache = self.draft_engine.rollback_ring(
                self.draft_cache, self.draft_pos, d_end, d_from, gamma)
