"""Transprecision self-speculative decoding: posit8 draft, target verify.

The paper's TALU switches precision at runtime on ONE datapath; the
serving-side analogue is to run the SAME weights twice per chunk at two
precisions: ``gamma`` cheap autoregressive *draft* steps under a derived
low-precision policy (posit8 weight compute + posit8 KV ring by default,
``core.transprecision.draft_policy``), then ONE *verify* pass under the
full-precision target policy that scores all gamma+1 chunk positions at
once (``models.serve_model.verify_step``).  Draft tokens that match the
target's greedy choice commit; the first mismatch yields the target's own
token as a free bonus, and the speculatively written K/V rows past the
commit point are **rolled back**:

* ring layout — rewind the per-slot ``pos`` vector and scrub the
  rolled-back code/scale rows to their init values, so the cache is
  bit-identical to one that never drafted;
* paged layout — truncate the slot's page list to the committed length,
  return orphaned pages through the refcounted allocator, and scrub the
  rolled-back pool rows.

Because the verify pass evaluates the exact decode-path math per token
(``chunk_decode_attention`` masks rejected rows to exact zeros), greedy
speculative decode emits token-for-token the same stream as baseline
greedy decode — the draft precision only moves the ACCEPTANCE RATE, i.e.
how many target-model steps each emitted token costs, never the output.

Draft-cache lifecycle: the draft ring mirrors the committed prefix.  When
every draft in a round is accepted the draft cache is one committed row
short (the last draft token was never fed through the draft model); that
slot's next round spends its first draft step catching up (output
discarded) and proposes gamma-1 tokens instead of gamma.  Lag never
exceeds one row.

Known boundary semantics (vs the baseline engine):

* near the CACHE cap a verify chunk needs gamma+1 rows of headroom, so a
  slot finishes once ``slot_pos > max_len - (gamma+1)`` — up to gamma
  tokens earlier than baseline's ``max_len - 1`` stop.  Streams are
  token-identical whenever generation is ``max_new``-bound (the normal
  serving regime); cap-truncated requests end a little shorter.  A
  dynamic chunk shrink for the last rounds is a ROADMAP follow-on.
* stream identity is bit-exact on the CPU/reference backend (what CI
  pins).  On accelerators the baseline decode reads through the fused
  Pallas kernels while the verify chunk reads through gather+decode XLA
  attention — different summation orders, so near-tied logits could in
  principle argmax differently until the fused chunk-verify kernel
  (ROADMAP) lands.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.transprecision import BF16, TCPolicy, draft_policy
from ..models import lm
from ..models.serve_model import (decode_step, init_cache, prefill,
                                  verify_step)
from .engine import Request, ServeConfig, ServingEngine
from .paged import pages_for

_SCRUB_LEAVES = ("k", "v", "k_scale", "v_scale")


def rollback_ring_cache(cache, new_pos, old_pos):
    """Rewind a ring-layout cache: set ``pos`` to ``new_pos`` (B,) and
    scrub every attention K/V row in [new_pos, old_pos) back to its init
    value (codes/floats 0, scales 1.0) — bit-identical to a cache that
    never wrote those rows.  No wraparound: row index == position, which
    ``verify_step`` guarantees by refusing sliding-window configs."""
    new = jnp.asarray(new_pos, jnp.int32)
    old = jnp.asarray(old_pos, jnp.int32)

    def scrub_block(blk, stacked):
        # blocks leaves carry a leading period-stack axis (P, B, W, ...);
        # tail leaves are plain (B, W, ...)
        out = dict(blk)
        for name in _SCRUB_LEAVES:
            if name not in blk:
                continue
            leaf = blk[name]
            w = leaf.shape[2 if stacked else 1]
            ar = jnp.arange(w, dtype=jnp.int32)[None, :]
            mask = (ar >= new[:, None]) & (ar < old[:, None])   # (B, W)
            lead = (1,) if stacked else ()
            trail = (1,) * (leaf.ndim - len(lead) - 2)
            mask = mask.reshape(lead + mask.shape + trail)
            init = 1.0 if name.endswith("_scale") else 0
            out[name] = jnp.where(mask, jnp.asarray(init, leaf.dtype), leaf)
        return out

    new_cache = dict(cache)
    new_cache["blocks"] = tuple(scrub_block(b, True) for b in cache["blocks"])
    if "tail" in cache:
        new_cache["tail"] = tuple(scrub_block(b, False)
                                  for b in cache["tail"])
    new_cache["pos"] = new
    return new_cache


def rollback_paged_cache(cache, new_pos, scrub_rows):
    """Rewind a paged-layout cache: set ``pos`` to ``new_pos`` (B,) and
    scrub the flat pool rows in ``scrub_rows`` (fixed-size (N,) i32,
    padded with trash row 0 — writes there are benign by construction)
    back to init values.  Page-table truncation and allocator frees are
    the engine's host-side half of the rollback."""
    rows = jnp.asarray(scrub_rows, jnp.int32)

    def scrub_block(blk, stacked):
        # blocks pool leaves carry a leading period-stack axis (P, R, ...);
        # tail leaves are plain (R, ...)
        out = dict(blk)
        for name in _SCRUB_LEAVES:
            if name not in blk:
                continue
            leaf = blk[name]
            init = jnp.asarray(1.0 if name.endswith("_scale") else 0,
                               leaf.dtype)
            out[name] = (leaf.at[:, rows].set(init) if stacked
                         else leaf.at[rows].set(init))
        return out

    new_cache = dict(cache)
    new_cache["blocks"] = tuple(scrub_block(b, True) for b in cache["blocks"])
    if "tail" in cache:
        new_cache["tail"] = tuple(scrub_block(b, False)
                                  for b in cache["tail"])
    new_cache["pos"] = jnp.asarray(new_pos, jnp.int32)
    return new_cache


class SpeculativeEngine(ServingEngine):
    """Continuous-batching engine with self-speculative greedy decode.

    Per round (one ``step()``): gamma lockstep draft ``decode_step``s
    under the draft policy, one ``verify_step`` under the target policy,
    per-slot acceptance, KV rollback.  Greedy-only: requests whose
    resolved temperature is > 0 are rejected at admission (acceptance
    compares argmax streams; stochastic acceptance is a follow-on).
    """

    def __init__(self, cfg: lm.ModelCfg, params, scfg: ServeConfig,
                 policy: TCPolicy = BF16, *, gamma: int = 4,
                 draft_weights_fmt: str = "posit8_2",
                 draft_kv_format: str = "posit8"):
        if gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {gamma}")
        if any(bt != "attn" for bt in cfg.block_types) or cfg.window \
                or cfg.family in ("moe", "audio"):
            raise ValueError(
                "speculative decoding needs a decoder-only attention "
                "stack without MoE or sliding windows (rollback is a row "
                f"rewind); {cfg.name} is not one")
        super().__init__(cfg, params, scfg, policy)
        self.gamma = gamma
        self._T = gamma + 1                     # verify chunk length
        if scfg.max_len <= self._T:
            raise ValueError(f"max_len {scfg.max_len} leaves no room for a "
                             f"gamma+1 = {self._T} verify chunk")
        self.draft = draft_policy(self.policy, weights_fmt=draft_weights_fmt,
                                  kv_format=draft_kv_format)
        b, L = scfg.max_batch, scfg.max_len
        self.draft_cache = init_cache(cfg, b, L, policy=self.draft)
        self.draft_cache["pos"] = jnp.zeros((b,), jnp.int32)
        self.draft_pos = np.zeros(b, np.int64)  # committed draft rows/slot
        # committed token the draft cache is missing (all-accepted rounds
        # leave the draft one row behind); None = in sync
        self._lag_tok: List[Optional[int]] = [None] * b

        self._draft_decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, cfg, self.draft))
        self._draft_prefill = jax.jit(
            lambda p, batch: prefill(p, batch, cfg, L, self.draft))
        self._verify = jax.jit(
            lambda p, c, t: verify_step(p, c, t, cfg, self.policy))
        donate = () if jax.default_backend() == "cpu" else (0,)
        self._draft_merge = jax.jit(self._merge_prefill,
                                    donate_argnums=donate)
        self._rb_ring = jax.jit(rollback_ring_cache, donate_argnums=donate)
        self._rb_paged = jax.jit(rollback_paged_cache, donate_argnums=donate)
        self.stats.update(spec_rounds=0, draft_steps=0, drafts_proposed=0,
                          drafts_accepted=0)
        # the draft ring is real HBM: re-report the footprint including it
        self.stats["kv_cache_bytes"] = self.kv_cache_bytes()

    # ---- cache footprint (target cache + the dense draft ring) ----
    def _draft_kv_bytes(self) -> int:
        """The draft ring's reserved bytes (a dense per-slot max_len ring
        at draft precision — always fully reserved, never paged).  0 while
        the base __init__ runs, before the draft cache exists."""
        draft_cache = getattr(self, "draft_cache", None)
        if draft_cache is None:
            return 0
        return self._kv_bytes(cache=draft_cache)

    def kv_cache_bytes(self) -> int:
        return super().kv_cache_bytes() + self._draft_kv_bytes()

    def kv_cache_live_bytes(self) -> int:
        return super().kv_cache_live_bytes() + self._draft_kv_bytes()

    def kv_cache_peak_live_bytes(self) -> int:
        return super().kv_cache_peak_live_bytes() + self._draft_kv_bytes()

    # ---- admission ----
    def _reject_reason(self, req: Request) -> Optional[str]:
        r = super()._reject_reason(req)
        if r is not None:
            return r
        if len(req.prompt) > self.scfg.max_len - self._T:
            return (f"prompt length {len(req.prompt)} > max_len - (gamma+1)"
                    f" = {self.scfg.max_len - self._T}: no room for a "
                    "verify chunk")
        if self._req_temp(req) > 0:
            return ("speculative decoding is greedy-only; set "
                    "Request.temperature=0 (or serve through the baseline "
                    "engine)")
        return None

    def _worst_pages(self, req: Request) -> int:
        """Worst-case page demand including the verify chunk's transient
        rows: a round may write gamma+1 rows past the committed length
        before rolling back, so the reservation covers committed + T."""
        s = len(req.prompt)
        tokens = min(max(s + req.max_new, s + 1) + self._T,
                     self.scfg.max_len)
        return pages_for(tokens, self.allocator.page_size)

    def add_request(self, req: Request) -> bool:
        reject = self._reject_reason(req)
        if reject is not None:
            raise ValueError(f"{reject}; reject before admission")
        if not super().add_request(req):
            return False
        slot = next((i for i, r in enumerate(self.slot_req) if r is req),
                    None)
        if slot is None:        # finished at admission (max_new<=1 / EOS)
            return True
        # draft-cache lifecycle: mirror the prompt into the draft ring so
        # round 1 drafts from the same committed prefix as the target
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        _, dc1 = self._draft_prefill(self.params, {"tokens": prompt})
        self.draft_cache = self._draft_merge(
            self.draft_cache, dc1, jnp.asarray(slot, jnp.int32), None)
        self.draft_pos[slot] = len(req.prompt)
        self._lag_tok[slot] = None
        return True

    # ---- one speculative round for the whole batch ----
    def step(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        b, gamma, T = self.scfg.max_batch, self.gamma, self._T
        pre_pos = self.slot_pos.copy()          # committed rows per slot
        pre_draft = self.draft_pos.copy()

        # ---- draft phase: gamma lockstep low-precision steps ----
        cur = np.zeros((b, 1), np.int32)
        proposals = np.zeros((b, gamma), np.int32)
        nprop = np.zeros(b, np.int64)
        catchup = np.zeros(b, bool)
        for i in active:
            if self._lag_tok[i] is not None:
                cur[i, 0] = self._lag_tok[i]
                catchup[i] = True
            else:
                cur[i, 0] = self.last_tok[i, 0]
        for s in range(gamma):
            logits_d, self.draft_cache = self._draft_decode(
                self.params, self.draft_cache, jnp.asarray(cur))
            toks = np.asarray(logits_d)[:, : self.cfg.vocab].argmax(-1)
            self.stats["draft_steps"] += 1
            for i in active:
                if s == 0 and catchup[i]:
                    # catch-up: the output re-predicts a token we already
                    # committed; discard it and feed the real one next
                    cur[i, 0] = self.last_tok[i, 0]
                    continue
                proposals[i, nprop[i]] = toks[i]
                nprop[i] += 1
                cur[i, 0] = toks[i]
        self.stats["drafts_proposed"] += int(nprop[active].sum())

        # ---- verify phase: one target-precision chunk pass ----
        chunk = np.zeros((b, T), np.int32)
        for i in active:
            chunk[i, 0] = self.last_tok[i, 0]
            chunk[i, 1:1 + nprop[i]] = proposals[i, : nprop[i]]
        if self.paged:
            grew = False
            for i in active:
                need = self.slot_pages[i].pages_needed(self.slot_pos[i] + T)
                if need:
                    pages = self.allocator.alloc(need)
                    if pages is None:
                        raise RuntimeError(
                            "paged KV pool exhausted before a verify chunk "
                            "— the speculative reservation invariant was "
                            "violated")
                    self.slot_pages[i].pages.extend(pages)
                    self._table[i] = self.slot_pages[i].table_row(self._pmax)
                    grew = True
            if grew:
                self.cache["page_table"] = jnp.asarray(self._table)
            self.stats["peak_live_pages"] = max(
                self.stats["peak_live_pages"], self.allocator.live_pages)
        # page lists as of the verify write extent (rollback scrubs
        # against these, BEFORE truncation/free)
        old_pages = ([list(self.slot_pages[i].pages) for i in range(b)]
                     if self.paged else None)
        logits_v, self.cache = self._verify(self.params, self.cache,
                                            jnp.asarray(chunk))
        g = np.asarray(logits_v)[..., : self.cfg.vocab].argmax(-1)  # (B, T)
        self.stats["decode_steps"] += 1
        self.stats["spec_rounds"] += 1

        # ---- per-slot acceptance + commit ----
        for i in active:
            req = self.slot_req[i]
            n = int(nprop[i])
            k = 0
            while k < n and proposals[i, k] == g[i, k]:
                k += 1
            # emission budget: keep the stream identical to baseline
            # greedy, which stops at exactly max_new tokens
            k = min(k, req.max_new - len(req.out_tokens) - 1)
            emitted = [int(t) for t in proposals[i, :k]] + [int(g[i, k])]
            eos = self.scfg.eos_id
            if eos is not None and eos in emitted:
                emitted = emitted[: emitted.index(eos) + 1]
            # emitted tokens are accepted drafts plus (unless an EOS draft
            # truncated the list first) one non-draft bonus token
            self.stats["drafts_accepted"] += min(len(emitted), k)
            req.out_tokens.extend(emitted)
            self.stats["tokens"] += len(emitted)
            self.last_tok[i, 0] = emitted[-1]
            self.slot_pos[i] = pre_pos[i] + len(emitted)
            # draft sync: rows the draft holds for the committed prefix
            drafted_rows = pre_draft[i] + gamma
            self.draft_pos[i] = min(drafted_rows, self.slot_pos[i])
            lag = int(self.slot_pos[i] - self.draft_pos[i])
            self._lag_tok[i] = int(chunk[i, k]) if lag else None
            if (len(req.out_tokens) >= req.max_new
                    or (eos is not None and emitted[-1] == eos)
                    or self.slot_pos[i] > self.scfg.max_len - T):
                req.done = True
                self._free_request_slot(i)      # resets slot_pos/draft state
                self.draft_pos[i] = 0
                self._lag_tok[i] = None

        # ---- KV rollback: target cache ----
        new_pos = self.slot_pos.copy()          # post-free (0 for done/idle)
        if self.paged:
            ps = self.allocator.page_size
            scrub = np.zeros(b * T, np.int64)   # padded with trash row 0
            nscrub = 0
            truncated = False
            for i in active:
                if self.slot_req[i] is None:    # freed above: pages already
                    continue                    # back in the pool
                sp = self.slot_pages[i]
                keep = pages_for(int(new_pos[i]), ps)
                orphans = sp.pages[keep:]
                for p in range(int(new_pos[i]), int(pre_pos[i]) + T):
                    scrub[nscrub] = old_pages[i][p // ps] * ps + p % ps
                    nscrub += 1
                if orphans:
                    self.allocator.free(orphans)
                    del sp.pages[keep:]
                    self._table[i] = sp.table_row(self._pmax)
                    truncated = True
            if truncated:
                self.cache["page_table"] = jnp.asarray(self._table)
            self.cache = self._rb_paged(self.cache, new_pos,
                                        jnp.asarray(scrub, jnp.int32))
        else:
            self.cache = self._rb_ring(self.cache, new_pos, pre_pos + T)
        # ---- KV rollback: draft ring (always ring layout) ----
        self.draft_cache = self._rb_ring(self.draft_cache, self.draft_pos,
                                         pre_draft + gamma)
