"""Paged KV-cache block allocator: free-list pages, per-sequence tables.

Host-side bookkeeping for the paged pool in ``kernels/paged_kv.py`` —
the vLLM-style split where the device holds a flat page pool and this
module decides which physical page each sequence's logical page maps to.

* ``PageAllocator`` — fixed population of ``num_pages`` pages of
  ``page_size`` token rows.  Page 0 is reserved as the *trash page*:
  idle slots and unallocated page-table entries point at it, so device
  code never needs a "no page" sentinel (reads there are masked by
  ``seq_lens``; writes are garbage by construction).
* Pages are refcounted so ``fork`` can share a prefix between sequences
  (copy-on-write page sharing — the allocator half of prefix caching;
  the engine-side fork is a ROADMAP follow-on).  ``free`` decrements and
  only returns a page to the free list when its last owner drops it.
* ``SlotPages`` — one sequence's page list + grow/seq-len logic; the
  engine keeps one per slot and mirrors it into the device page table.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import List, Optional

import numpy as np

from ..obs.tracer import NULL_SPAN

TRASH_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` rows."""
    return -(-max(tokens, 0) // page_size)


class PageAllocator:
    """Free-list allocator over a fixed page population (page 0 reserved).

    ``metrics``/``tracer`` (:mod:`repro.obs`) are optional: when given,
    alloc/free/fork maintain ``pages.*`` counters plus the ``pages.live``
    gauge, and each mutation gets a span (cat ``alloc``) while tracing
    is enabled."""

    def __init__(self, num_pages: int, page_size: int, *,
                 metrics=None, tracer=None, faults=None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.metrics = metrics
        self.tracer = tracer
        # optional FaultInjector (serve/faults.py): pool_dry faults force
        # alloc to report a dry pool, fork_fail faults raise from fork
        self.faults = faults
        # LIFO free list keeps recently-freed (cache-warm) pages hot
        self._free: List[int] = list(range(num_pages - 1, TRASH_PAGE, -1))
        self._refs = np.zeros(num_pages, np.int32)
        self._refs[TRASH_PAGE] = 1          # never allocatable

    _COUNTERS = {"alloc": "pages.allocated", "free": "pages.freed",
                 "fork": "pages.forked"}

    def _count(self, op: str, n: int) -> None:
        m = self.metrics
        if m is None:
            return
        m.counter(f"pages.{op}_calls").inc()
        m.counter(self._COUNTERS[op]).inc(n)
        m.gauge("pages.live").set(self.live_pages)

    def _span(self, op: str):
        tr = self.tracer
        if tr is None:
            return NULL_SPAN
        return tr.span(f"pages.{op}", cat="alloc")

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Allocated pages (excludes the trash page)."""
        return self.num_pages - 1 - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages (refcount 1 each); None if insufficient —
        all-or-nothing, so a partially admissible request never strands
        pages."""
        with self._span("alloc"):
            if n > len(self._free) or (
                    self.faults is not None and self.faults.on_alloc(n)):
                if self.metrics is not None:
                    self.metrics.counter("pages.alloc_failures").inc()
                return None
            pages = [self._free.pop() for _ in range(n)]
            self._refs[pages] = 1
            self._count("alloc", n)
            return pages

    def _check_pages(self, pages: List[int], op: str) -> None:
        """Validate a page list BEFORE mutating any state, so an invalid
        call raises a clear error and leaves the free list untouched
        (partial mutation is how free lists get corrupted).  Catches:
        out-of-range ids (negative ids would silently wrap under numpy
        indexing), the reserved trash page 0, and pages whose refcount
        cannot cover the requested drops (double free / fork-after-free),
        including duplicates within one call."""
        for p, n in Counter(pages).items():
            if not 0 <= p < self.num_pages:
                raise ValueError(f"{op} of out-of-range page {p} "
                                 f"(pool holds {self.num_pages})")
            if p == TRASH_PAGE:
                raise ValueError(f"{op} of reserved trash page 0")
            if self._refs[p] <= 0:
                raise ValueError(
                    f"{op} of page {p} that is not allocated "
                    f"({'double free' if op == 'free' else 'freed page'})")
            if op == "free" and self._refs[p] < n:
                raise ValueError(f"double free of page {p} "
                                 f"({n} drops, refcount {self._refs[p]})")

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page; pages return to the free list at
        refcount 0.  All-or-nothing: an invalid list (double free, trash
        page, out of range) raises before any refcount moves."""
        with self._span("free"):
            self._check_pages(pages, "free")
            for p in pages:
                self._refs[p] -= 1
                if self._refs[p] == 0:
                    self._free.append(p)
            self._count("free", len(pages))

    def fork(self, pages: List[int]) -> List[int]:
        """Share ``pages`` with a new owner (prefix sharing): bump each
        refcount and return the same physical page list.  The caller must
        copy-on-write before mutating a page whose refcount is > 1.
        All-or-nothing: forking a freed / trash / out-of-range page raises
        before any refcount moves."""
        with self._span("fork"):
            if self.faults is not None:
                self.faults.on_fork()
            self._check_pages(pages, "fork")
            for p in pages:
                self._refs[p] += 1
            self._count("fork", len(pages))
            return list(pages)

    def ref_count(self, page: int) -> int:
        return int(self._refs[page])

    def assert_consistent(self) -> None:
        """Allocator invariant check, O(num_pages): the free list and the
        refcounted (live) set partition the non-trash pages exactly —
        every page is free with refcount 0 or allocated with refcount
        >= 1, the free list holds no duplicates, and the trash page is
        permanently referenced and never free.  Raises AssertionError
        with the offending pages; call from test teardown and the chaos
        suite (a leak or double-free shows up here even when the engine
        happens to keep working)."""
        free_set = set(self._free)
        if len(free_set) != len(self._free):
            dup = [p for p, n in Counter(self._free).items() if n > 1]
            raise AssertionError(f"free list holds duplicates: {dup}")
        if TRASH_PAGE in free_set:
            raise AssertionError("trash page 0 is on the free list")
        if self._refs[TRASH_PAGE] != 1:
            raise AssertionError(
                f"trash page refcount {int(self._refs[TRASH_PAGE])} != 1")
        if (self._refs < 0).any():
            bad = np.nonzero(self._refs < 0)[0].tolist()
            raise AssertionError(f"negative refcounts on pages {bad}")
        bad = [p for p in range(1, self.num_pages)
               if (p in free_set) == (self._refs[p] > 0)]
        if bad:
            detail = {p: (int(self._refs[p]), p in free_set) for p in bad}
            raise AssertionError(
                "refcount/free-list mismatch (page: (refs, on_free)): "
                f"{detail}")


@dataclasses.dataclass
class SlotPages:
    """One sequence's page list (logical order) + growth bookkeeping.
    Sequence length itself stays the engine's (``slot_pos``) — one source
    of truth; callers pass the target length to ``pages_needed``."""

    page_size: int
    pages: List[int] = dataclasses.field(default_factory=list)

    def pages_needed(self, new_len: int) -> int:
        """Extra pages required to grow to ``new_len`` tokens."""
        return max(pages_for(new_len, self.page_size) - len(self.pages), 0)

    def table_row(self, pmax: int) -> np.ndarray:
        """(pmax,) i32 device page-table row (trash-padded)."""
        row = np.full(pmax, TRASH_PAGE, np.int32)
        row[: len(self.pages)] = self.pages
        return row
