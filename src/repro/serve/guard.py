"""Numeric quarantine + automatic precision-fallback re-decode.

The paper's thesis — runtime precision reconfiguration on one datapath —
applied as a *failure policy*: when a slot's decode logits come back
non-finite (a posit8 weight path blowing up, or an injected
``poison_logits`` fault), the slot is quarantined for that round and its
logits row is recomputed up a **precision-escalation ladder** derived
from the engine's own policy (posit8 → posit16 → full target precision)
until the row reads finite again.  Un-faulted slots keep their original
logits bit-for-bit, so a quarantine never perturbs its batch neighbours.

Mechanics per quarantined round:

* the driver retains the pre-``generate`` decode state (guard-armed
  engines run with ``donate=False`` — the fallback must be able to
  re-read it) and hands it here with the host logits copy;
* each ladder rung is a lazily built :class:`TransprecisionEngine`
  (``donate=False``, stage prefix ``guard<k>.``) sharing the main
  engine's tracer/metrics; its ``generate`` re-runs the SAME round from
  the retained state and only the quarantined slot's logits row is
  taken.  The fallback's cache writes are discarded — the main cache
  already holds the original round's K/V (poison is a logits-level
  event), so neighbours' streams and cache rows are untouched;
* a request's achieved ladder level is **sticky** (``guard.levels`` by
  uid): a slot that needed posit16 last round starts there next time it
  faults instead of re-proving the lower rungs;
* if the ladder is exhausted and the row is still non-finite the request
  terminates with ``error`` (slot + pages reclaimed by the engine) —
  quarantine degrades one request, never the batch.

Counters in the shared registry: ``guard.nonfinite_rows`` (detections),
``guard.quarantined`` (slot-rounds quarantined), ``guard.fallbacks``
(fallback re-decodes run), ``guard.exhausted`` (requests failed through
the whole ladder).  Disabled (``guard=None`` engines), the only hot-path
cost is one ``is not None`` check per decode round.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.transprecision import TCPolicy
from .engine_api import TransprecisionEngine

__all__ = ["GuardConfig", "NumericGuard", "fallback_ladder"]

# roles the ladder escalates (weight compute + activations); KV
# format/layout stay FIXED so every rung consumes the same decode-state
# pytree the main engine produced
_LADDER_ROLES = ("attn_weights", "mlp_weights", "embed_weights",
                 "activations")


def _up(fmt: Optional[str]) -> Optional[str]:
    """One notch up: posit8/int8-class formats → posit16; 16-bit and up
    → full precision (None)."""
    if fmt is None:
        return None
    return None if "16" in fmt else "posit16_2"


def fallback_ladder(policy: TCPolicy) -> Tuple[TCPolicy, ...]:
    """Precision-escalation ladder for ``policy``: successive rungs
    upgrade every compute role one notch until full precision, dropping
    layer/node overrides (escalation is uniform).  A policy already at
    full precision gets a single same-precision retry rung — transient
    numeric state is still worth one re-decode."""
    rungs, cur = [], policy
    while True:
        nxt = {r: _up(getattr(cur, r)) for r in _LADDER_ROLES}
        if all(nxt[r] == getattr(cur, r) for r in _LADDER_ROLES) \
                and not cur.layer_overrides and not cur.node_overrides:
            break
        cur = dataclasses.replace(
            cur, name=f"{policy.name}+guard{len(rungs) + 1}",
            layer_overrides=(), node_overrides=(), **nxt)
        rungs.append(cur)
    if not rungs:
        rungs.append(dataclasses.replace(policy,
                                         name=policy.name + "+guard_retry"))
    return tuple(rungs)


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """``ladder`` overrides the derived escalation ladder;
    ``max_levels`` truncates it (1 = a single fallback rung)."""
    max_levels: Optional[int] = None
    ladder: Optional[Tuple[TCPolicy, ...]] = None


class NumericGuard:
    """Per-slot non-finite-logits quarantine for a ``ServingEngine``."""

    def __init__(self, engine, gcfg: GuardConfig = GuardConfig()):
        self.engine = engine
        ladder = (gcfg.ladder if gcfg.ladder is not None
                  else fallback_ladder(engine.policy))
        if gcfg.max_levels is not None:
            ladder = ladder[:gcfg.max_levels]
        if not ladder:
            raise ValueError("guard needs at least one ladder level")
        self.ladder: Tuple[TCPolicy, ...] = tuple(ladder)
        # uid -> achieved level (sticky; 0 = base policy, never stored)
        self.levels: Dict[int, int] = {}
        m = engine.metrics
        self._c_rows = m.counter("guard.nonfinite_rows")
        self._c_quar = m.counter("guard.quarantined")
        self._c_fall = m.counter("guard.fallbacks")
        self._c_exh = m.counter("guard.exhausted")
        self._engines: Dict[int, TransprecisionEngine] = {}

    def level(self, uid: int) -> int:
        """Achieved ladder level for a request (0 = base policy)."""
        return self.levels.get(uid, 0)

    def _engine_for(self, lvl: int) -> TransprecisionEngine:
        """Lazily built rung engine (compiles its own ``generate`` on
        first quarantine at this level — a one-off cost per level)."""
        eng = self._engines.get(lvl)
        if eng is None:
            base = self.engine.engine
            eng = TransprecisionEngine(
                self.engine.cfg, self.ladder[lvl - 1], base.max_batch,
                base.max_len, num_pages=base.num_pages,
                attn_impl=base.attn_impl, donate=False,
                tracer=self.engine.tracer, metrics=self.engine.metrics,
                stage_prefix=f"guard{lvl}.")
            self._engines[lvl] = eng
        return eng

    def check_round(self, prev_state, logits: np.ndarray, active,
                    poisons: Optional[Dict[int, object]] = None) -> None:
        """Scan the round's host logits (mutated in place) for non-finite
        rows among ``active`` slots; re-decode each such row from
        ``prev_state`` up the ladder.  Requests that stay non-finite
        through the top rung are marked ``done`` with an ``error`` — the
        engine frees their slot/pages afterwards.  ``poisons`` maps slots
        to injected faults whose ``fixed_by_level`` simulates a failure
        that only clears above a given precision."""
        poisons = poisons or {}
        eng = self.engine
        for i in active:
            if np.isfinite(logits[i]).all():
                continue
            req = eng.slot_req[i]
            self._c_rows.inc()
            self._c_quar.inc()
            fault = poisons.get(i)
            # sticky start: a request that already proved it needs level k
            # RETRIES at k first (lvl is pre-incremented in the loop) —
            # it must not skip past its achieved rung, or a second fault
            # on the same request would instantly exhaust the ladder
            lvl = max(self.levels.get(req.uid, 1) - 1, 0)
            with eng.tracer.span("guard.redecode", cat="guard",
                                 slot=i, uid=req.uid):
                while lvl < len(self.ladder):
                    lvl += 1
                    self._c_fall.inc()
                    fb = self._engine_for(lvl)
                    # dict() copy + donate=False on both engines: the
                    # retained state stays intact however often we re-run
                    _, fb_logits = fb.generate(eng.params,
                                               dict(prev_state))
                    row = np.asarray(fb_logits, np.float32)[i]
                    if fault is not None \
                            and lvl < getattr(fault, "fixed_by_level", 1):
                        row = np.full_like(row, np.nan)
                    if np.isfinite(row).all():
                        logits[i] = row
                        self.levels[req.uid] = lvl
                        break
                else:
                    self._c_exh.inc()
                    req.done = True
                    req.error = ("non-finite logits persisted through "
                                 f"the {len(self.ladder)}-level "
                                 "precision-fallback ladder")
