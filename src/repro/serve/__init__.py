from .engine import Request, ServeConfig, ServingEngine
from .engine_api import (Prefix, TransprecisionEngine, rollback_paged_cache,
                         rollback_ring_cache)
from .distributed import (distributed_decode_attention,
                          make_distributed_decode_step,
                          make_distributed_engine)
from .faults import (Fault, FaultInjector, FaultPlan, InjectedFault,
                     RetryPolicy)
from .guard import GuardConfig, NumericGuard, fallback_ladder
from .orchestrator import Orchestrator, OrchestratorConfig, StreamingRequest
from .paged import PageAllocator, SlotPages, pages_for
from .speculative import SpeculativeEngine
