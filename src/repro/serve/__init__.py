from .engine import ServeConfig, ServingEngine
from .distributed import distributed_decode_attention, make_distributed_decode_step
