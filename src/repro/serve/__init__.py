from .engine import Request, ServeConfig, ServingEngine
from .distributed import distributed_decode_attention, make_distributed_decode_step
from .paged import PageAllocator, SlotPages, pages_for
from .speculative import SpeculativeEngine
