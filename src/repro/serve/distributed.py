"""Distributed decode attention: KV-sequence sharding + log-sum-exp combine.

The decode cells keep a KV cache of up to 512k tokens; sharding its sequence
axis over "model" is the only way it fits, but a naive softmax over a
sharded axis makes XLA all-gather the WHOLE cache every token
(O(B*W*nkv*hd) ICI bytes — the dominant collective in the baseline
dry-run).  The fix is the classic distributed-softmax identity: each shard
reduces its local slice to

    (m_i = max_s, l_i = sum exp(s - m_i), o_i = sum exp(s - m_i) v)

and the combine is an O(B*nh*hd) psum:

    m = pmax(m_i);  out = psum(o_i * e^{m_i - m}) / psum(l_i * e^{m_i - m})

Collective volume drops from O(KV-cache) to O(one activation row) —
independent of sequence length.  This is the TPU-native analogue of the
paper's TALU-V: many small units each owning a slice of the operand vector,
combined with a tree reduction.

Implemented with ``shard_map`` manual over "model" only (data/pod stay
automatic), so it composes with the pjit-sharded rest of the decode step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models import serve_model
from ..models.attention import NEG_INF


def _shard_map(fn, mesh: Mesh, in_specs, out_specs, axis: str):
    """shard_map across JAX versions: ``jax.shard_map`` (new) with manual
    ``axis`` only, or ``jax.experimental.shard_map`` (<=0.4.x) with the
    other mesh axes auto."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names={axis})
    from jax.experimental.shard_map import shard_map as sm
    auto = frozenset(set(mesh.axis_names) - {axis})
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False, auto=auto)


def _local_lse(q, k, v, start, cache_len):
    """Partial attention over a local KV slice.

    q: (B, 1, nkv, grp, hd); k/v: (B, Wl, nkv, hd); start: global index of
    this slice; cache_len scalar (shared) or (B,) per-slot.  Returns
    (o (B,nkv,grp,hd), l (B,nkv,grp), m (B,nkv,grp)).
    """
    b, wl = k.shape[0], k.shape[1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)[..., 0, :]
    idx = start + jnp.arange(wl)
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    valid = idx[None, :] < cl[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = scores.max(-1)                                    # (B, nkv, grp)
    p = jnp.exp(scores - m[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v).astype(jnp.float32)
    return o, l, m


def distributed_decode_attention(mesh: Mesh, axis: str = "model",
                                 kv_spec=None, *, paged: bool = False,
                                 page_size: int = 16):
    """Returns an ``attn_impl(q, k_cache, v_cache, cache_len)`` whose KV
    cache is *manually* sharded along ``axis`` on its sequence dim.

    With a posit ``kv_spec`` (``core.transprecision.KVStorage``) the impl
    speaks the packed protocol (``attn.packed_kv = True``): the wire/HBM
    payload is posit CODES + per-row scales sharded along the sequence
    axis — each shard decodes its slice locally right before the partial
    LSE reduction, so full-precision K/V never cross HBM or ICI and the
    sharded cache stays ``bits/16`` of the bf16 footprint.

    With ``paged=True`` (posit spec only) the impl speaks the *paged*
    protocol (``attn.paged_kv = True``): the pool's flat rows are sharded
    along ``axis`` — each shard owns a contiguous physical page range —
    while the page table and per-slot lengths ship replicated next to the
    codes + scales.  A shard gathers only the table entries that fall in
    its page range, masks the rest, and joins the same O(activation-row)
    LSE combine; collective volume stays independent of context length
    AND of how many pages are live.  Requires num_pages divisible by the
    ``axis`` size (pages never straddle shards)."""
    n_shard = mesh.shape[axis]
    if paged and kv_spec is not None and kv_spec.is_posit:
        from ..kernels import kv_cache as kv_kernels

        def attn_paged(q, k_codes, v_codes, seq_lens, *, k_scale, v_scale,
                       page_table, page_size=page_size, **_):
            r, nkv, _ = k_codes.shape
            b, _, nh, hd = q.shape
            grp = nh // nkv
            qg = q.reshape(b, 1, nkv, grp, hd) * (hd ** -0.5)
            lens = jnp.broadcast_to(jnp.asarray(seq_lens, jnp.int32), (b,))
            tbl = jnp.asarray(page_table, jnp.int32)

            def shard_fn(qs, kc, ks, vc, vs, tb, ln):
                np_local = kc.shape[0] // page_size
                start = jax.lax.axis_index(axis) * np_local
                loc = tb - start                       # local page ids
                own = (loc >= 0) & (loc < np_local)    # (B, Pmax)
                rows = (jnp.clip(loc, 0, np_local - 1)[:, :, None]
                        * page_size + jnp.arange(page_size)).reshape(b, -1)
                kf = kv_kernels.decode_kv_rows(
                    kc[rows], ks[rows][..., None], kv_spec.fmt,
                    kv_spec.packed)                    # (B, L, nkv, hd)
                vf = kv_kernels.decode_kv_rows(
                    vc[rows], vs[rows][..., None], kv_spec.fmt,
                    kv_spec.packed)
                s = jnp.einsum("bqkgh,bskh->bkgqs", qs,
                               kf).astype(jnp.float32)[..., 0, :]
                kpos = jnp.arange(rows.shape[1])
                valid = (jnp.repeat(own, page_size, axis=1)
                         & (kpos[None, :] < ln[:, None]))
                s = jnp.where(valid[:, None, None, :], s, NEG_INF)
                m = s.max(-1)
                p = jnp.exp(s - m[..., None])
                l = p.sum(-1)
                o = jnp.einsum("bkgs,bskh->bkgh", p.astype(vf.dtype),
                               vf).astype(jnp.float32)
                m_g = jax.lax.pmax(m, axis)
                corr = jnp.exp(m - m_g)
                num = jax.lax.psum(o * corr[..., None], axis)
                den = jax.lax.psum(l * corr, axis)
                return (num / jnp.maximum(den, 1e-30)[..., None]).astype(
                    q.dtype)

            out = _shard_map(
                shard_fn, mesh,
                in_specs=(P(), P(axis, None, None), P(axis, None),
                          P(axis, None, None), P(axis, None), P(), P()),
                out_specs=P(), axis=axis)(qg, k_codes, k_scale, v_codes,
                                          v_scale, tbl, lens)
            return out.reshape(b, 1, nh, hd)

        attn_paged.paged_kv = True
        return attn_paged
    if kv_spec is not None and kv_spec.is_posit:
        from ..kernels import kv_cache as kv_kernels

        def attn_packed(q, k_codes, v_codes, cache_len, *, k_scale, v_scale,
                        **_):
            b, w, nkv, _ = k_codes.shape
            nh, hd = q.shape[2], q.shape[3]
            grp = nh // nkv
            qg = q.reshape(b, 1, nkv, grp, hd) * (hd ** -0.5)
            cache_len = jnp.asarray(cache_len)

            def shard_fn(qs, kc, ks, vc, vs, cl):
                wl = kc.shape[1]
                start = jax.lax.axis_index(axis) * wl
                kf = kv_kernels.decode_kv_rows(kc, ks[..., None],
                                               kv_spec.fmt, kv_spec.packed)
                vf = kv_kernels.decode_kv_rows(vc, vs[..., None],
                                               kv_spec.fmt, kv_spec.packed)
                o, l, m = _local_lse(qs, kf, vf, start, cl)
                m_g = jax.lax.pmax(m, axis)
                corr = jnp.exp(m - m_g)
                num = jax.lax.psum(o * corr[..., None], axis)
                den = jax.lax.psum(l * corr, axis)
                return (num / jnp.maximum(den, 1e-30)[..., None]).astype(
                    q.dtype)

            out = _shard_map(
                shard_fn, mesh,
                in_specs=(P(), P(None, axis, None, None),
                          P(None, axis, None),
                          P(None, axis, None, None),
                          P(None, axis, None), P()),
                out_specs=P(), axis=axis)(qg, k_codes, k_scale, v_codes,
                                          v_scale, cache_len)
            return out.reshape(b, 1, nh, hd)

        attn_packed.packed_kv = True
        return attn_packed

    def attn(q, k_cache, v_cache, cache_len, **_):
        b, w, nkv, hd = k_cache.shape
        nh = q.shape[2]
        grp = nh // nkv
        qg = (q.reshape(b, 1, nkv, grp, hd) * (hd ** -0.5))
        cache_len = jnp.asarray(cache_len)

        def shard_fn(qs, ks, vs, cl):
            wl = ks.shape[1]
            start = jax.lax.axis_index(axis) * wl
            o, l, m = _local_lse(qs, ks, vs, start, cl)
            m_g = jax.lax.pmax(m, axis)
            corr = jnp.exp(m - m_g)
            num = jax.lax.psum(o * corr[..., None], axis)
            den = jax.lax.psum(l * corr, axis)
            return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)

        out = _shard_map(
            shard_fn, mesh,
            in_specs=(P(), P(None, axis, None, None),
                      P(None, axis, None, None), P()),
            out_specs=P(), axis=axis)(qg, k_cache, v_cache, cache_len)
        return out.reshape(b, 1, nh, hd)

    return attn


def make_distributed_decode_step(cfg, policy, mesh: Mesh, rules,
                                 axis: str = "model"):
    """decode_step with the LSE-combined distributed attention plugged in."""
    from ..core.transprecision import kv_storage
    attn_impl = distributed_decode_attention(
        mesh, axis, kv_spec=kv_storage(policy),
        paged=getattr(policy, "kv_layout", "ring") == "paged",
        page_size=getattr(policy, "kv_page_size", 16))

    def step(params, cache, tok):
        if cfg.family == "vlm":
            return serve_model.decode_step(params, cache, None, cfg, policy,
                                           embeds=tok, attn_impl=attn_impl)
        return serve_model.decode_step(params, cache, tok, cfg, policy,
                                       attn_impl=attn_impl)

    return step


def make_distributed_engine(cfg, policy, mesh: Mesh, max_batch: int,
                            max_len: int, axis: str = "model", *,
                            num_pages=None):
    """A three-stage :class:`~repro.serve.engine_api.TransprecisionEngine`
    whose ``generate`` runs the LSE-combined KV-sharded attention — the
    disaggregated API and the distributed decode path are the same code,
    differing only in the plugged ``attn_impl``."""
    from ..core.transprecision import kv_storage
    from .engine_api import TransprecisionEngine
    attn_impl = distributed_decode_attention(
        mesh, axis, kv_spec=kv_storage(policy),
        paged=getattr(policy, "kv_layout", "ring") == "paged",
        page_size=getattr(policy, "kv_page_size", 16))
    return TransprecisionEngine(cfg, policy, max_batch, max_len,
                                num_pages=num_pages, attn_impl=attn_impl)
