"""Deterministic, seed-driven fault injection for the serving stack.

Chaos hardening needs failures that are *reproducible*: a
:class:`FaultPlan` is a static schedule of :class:`Fault`\\ s, each keyed
to a call-site ("site") and a 0-based call index at that site.  The
:class:`FaultInjector` keeps one monotonically increasing counter per
site; a fault fires on calls ``at <= n < at + count``.  Same plan, same
request stream → the same faults fire at the same points, so the chaos
suite (``tests/test_chaos.py``) can assert exact invariants instead of
"it usually survives".

Sites and what their faults do:

* ``<stage name>`` (``prefill`` / ``generate`` / ``insert`` / ``verify``
  / ``draft.generate`` / ...) — ``stage_error`` raises
  :class:`InjectedFault` *before* the stage dispatches (donated buffers
  are never consumed by a failed attempt), ``stage_delay`` sleeps
  ``delay_s`` first (injected straggler).  Transient stage errors are
  retried by the engine under a :class:`RetryPolicy`; persistent ones
  propagate to the driver (crash containment's job).
* ``alloc`` / ``fork`` — ``pool_dry`` makes ``PageAllocator.alloc``
  return None (admission queues / overcommit evicts), ``fork_fail``
  raises from ``fork``.
* ``round`` — one call per base-engine decode round: ``poison_logits``
  overwrites the chosen ``slot``'s logits row with NaN host-side
  (modeling a low-precision datapath blow-up); ``fixed_by_level`` says
  how far up the guard's precision-fallback ladder the fault persists
  (1 = the first fallback re-decode already reads finite).
* ``tokenize`` / ``detok`` / ``sched`` — ``tokenize_crash`` /
  ``detok_crash`` / ``sched_crash`` raise inside the orchestrator's
  worker loops (exercising loop-death containment).

Every hook is behind an ``if injector is not None`` check at the call
site — a disabled serving stack pays nothing.  Fired faults are appended
to ``injector.events`` (kind/site/call/slot/uid) and tick
``faults.injected`` + ``faults.<kind>`` counters in the shared metrics
registry.

``train/fault_tolerance.py`` (CrashBarrier's ``crash_at_steps``) is the
in-repo precedent; this module is the serving-side generalization.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Fault", "FaultPlan", "FaultInjector", "InjectedFault",
           "RetryPolicy"]

_STAGE_KINDS = ("stage_error", "stage_delay")
_SITE_OF = {"pool_dry": "alloc", "fork_fail": "fork",
            "poison_logits": "round", "tokenize_crash": "tokenize",
            "detok_crash": "detok", "sched_crash": "sched"}
KINDS = _STAGE_KINDS + tuple(_SITE_OF)


class InjectedFault(RuntimeError):
    """An injected failure.  ``transient`` marks faults a bounded retry
    is allowed to absorb; persistent ones must reach crash containment."""

    def __init__(self, msg: str, *, kind: str = "injected",
                 transient: bool = False):
        super().__init__(msg)
        self.kind = kind
        self.transient = transient


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for *transient* stage failures.

    ``max_attempts`` is the total number of tries (1 = no retry);
    the sleep before retry ``k`` (0-based) is
    ``min(backoff_s * multiplier**k, max_backoff_s)``."""
    max_attempts: int = 4
    backoff_s: float = 0.005
    multiplier: float = 2.0
    max_backoff_s: float = 0.25

    def delay(self, retry_index: int) -> float:
        return min(self.backoff_s * self.multiplier ** retry_index,
                   self.max_backoff_s)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.  ``at``/``count``: fire on calls
    ``[at, at + count)`` of this fault's site counter.  ``stage`` names
    the site for stage faults (other kinds have fixed sites)."""
    kind: str
    stage: str = ""
    at: int = 0
    count: int = 1
    transient: bool = True        # stage_error: retryable?
    delay_s: float = 0.02         # stage_delay: injected latency
    slot: int = 0                 # poison_logits: victim batch slot
    fixed_by_level: int = 1       # poison_logits: first guard level that
                                  # reads finite logits again

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {sorted(KINDS)})")
        if self.kind in _STAGE_KINDS and not self.stage:
            raise ValueError(f"{self.kind} needs a stage site name")

    @property
    def site(self) -> str:
        return self.stage if self.kind in _STAGE_KINDS \
            else _SITE_OF[self.kind]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A static fault schedule (plus seed provenance for random plans)."""
    faults: Tuple[Fault, ...] = ()
    seed: Optional[int] = None

    # stage sites random plans target (base + speculative engines)
    RANDOM_STAGES = ("prefill", "generate", "insert")

    @classmethod
    def random(cls, seed: int, n: int = 6, *, rounds: int = 40,
               slots: int = 2, lethal: bool = False,
               stages: Tuple[str, ...] = RANDOM_STAGES) -> "FaultPlan":
        """Seeded random schedule of ``n`` faults over the first
        ``rounds`` calls of each site.  Benign plans draw transient stage
        errors (retryable), stage delays, poisoned logits (guard-
        recoverable) and pool-dry allocs (queue/evict-recoverable);
        ``lethal`` adds persistent stage errors and loop crashes, whose
        only correct outcome is containment.  Pool-dry faults assume a
        ``page_overcommit`` engine (a reservation-mode engine treats a
        dry growth alloc as an invariant violation — by design)."""
        rng = np.random.default_rng(seed)
        kinds = ["stage_error", "stage_delay", "poison_logits", "pool_dry"]
        if lethal:
            kinds += ["stage_error_persistent", "detok_crash",
                      "tokenize_crash", "sched_crash"]
        faults: List[Fault] = []
        for _ in range(n):
            k = kinds[int(rng.integers(len(kinds)))]
            at = int(rng.integers(0, rounds))
            if k in ("stage_error", "stage_error_persistent"):
                faults.append(Fault(
                    "stage_error", stage=str(stages[int(rng.integers(
                        len(stages)))]), at=at,
                    count=int(rng.integers(1, 3)),
                    transient=(k == "stage_error")))
            elif k == "stage_delay":
                faults.append(Fault(
                    "stage_delay", stage=str(stages[int(rng.integers(
                        len(stages)))]), at=at,
                    delay_s=float(rng.uniform(0.005, 0.03))))
            elif k == "poison_logits":
                faults.append(Fault(
                    "poison_logits", at=at,
                    slot=int(rng.integers(slots)),
                    fixed_by_level=int(rng.integers(1, 3))))
            elif k == "pool_dry":
                faults.append(Fault("pool_dry", at=at,
                                    count=int(rng.integers(1, 3))))
            else:
                faults.append(Fault(k, at=at))
        return cls(tuple(faults), seed=seed)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """CLI-facing plan specs: ``none``, ``random:seed=3,n=6`` (keys:
        seed/n/rounds/slots/lethal), or a path to a JSON file holding a
        list of :class:`Fault` field dicts."""
        spec = spec.strip()
        if spec in ("", "none"):
            return cls()
        if spec.startswith("random:") or spec == "random":
            kv = {}
            for part in spec.partition(":")[2].split(","):
                if not part:
                    continue
                k, _, v = part.partition("=")
                kv[k.strip()] = v.strip()
            return cls.random(seed=int(kv.get("seed", 0)),
                              n=int(kv.get("n", 6)),
                              rounds=int(kv.get("rounds", 40)),
                              slots=int(kv.get("slots", 2)),
                              lethal=bool(int(kv.get("lethal", 0))))
        with open(spec) as f:
            return cls(tuple(Fault(**d) for d in json.load(f)))


class FaultInjector:
    """Threads a :class:`FaultPlan` through the serving stack's hook
    points.  Thread-safe: the scheduler, detokenizer and allocator all
    call in.  ``events`` records every fired fault."""

    def __init__(self, plan: FaultPlan, *, metrics=None):
        self.plan = plan
        self.metrics = metrics
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self._by_site: Dict[str, List[Fault]] = {}
        for f in plan.faults:
            self._by_site.setdefault(f.site, []).append(f)
        self.events: List[dict] = []
        self.uids_poisoned: set = set()

    def _fire(self, site: str) -> List[Fault]:
        """Advance ``site``'s call counter; return the faults scheduled
        for this call."""
        scheduled = self._by_site.get(site)
        with self._lock:
            n = self._counters.get(site, 0)
            self._counters[site] = n + 1
        if not scheduled:
            return []
        return [f for f in scheduled if f.at <= n < f.at + f.count]

    def _log(self, fault: Fault, site: str, **extra) -> None:
        with self._lock:
            call = self._counters.get(site, 1) - 1
            self.events.append({"kind": fault.kind, "site": site,
                                "call": call, **extra})
        if self.metrics is not None:
            self.metrics.counter("faults.injected").inc()
            self.metrics.counter(f"faults.{fault.kind}").inc()

    # ---- hook points ----
    def on_stage(self, name: str) -> None:
        """Engine-stage hook (``engine_api``), called BEFORE the stage
        dispatches: injected stragglers sleep, injected errors raise —
        a failed attempt never consumes donated buffers."""
        fired = self._fire(name)
        if not fired:
            return
        for f in fired:
            if f.kind == "stage_delay":
                self._log(f, name, delay_s=f.delay_s)
                time.sleep(f.delay_s)
        for f in fired:
            if f.kind == "stage_error":
                self._log(f, name, transient=f.transient)
                mode = "transient" if f.transient else "persistent"
                raise InjectedFault(
                    f"injected {mode} failure in stage {name} "
                    f"(call {self._counters[name] - 1})",
                    kind="stage_error", transient=f.transient)

    def on_alloc(self, n: int) -> bool:
        """PageAllocator.alloc hook: True forces a dry-pool result."""
        for f in self._fire("alloc"):
            if f.kind == "pool_dry":
                self._log(f, "alloc", pages=n)
                return True
        return False

    def on_fork(self) -> None:
        for f in self._fire("fork"):
            if f.kind == "fork_fail":
                self._log(f, "fork")
                raise InjectedFault("injected page-fork failure",
                                    kind="fork_fail")

    def poison_round(self, uid_by_slot: Dict[int, int]) -> Dict[int, Fault]:
        """Decode-round hook: which active slots get NaN logits this
        round.  Returns ``{slot: fault}``; the engine overwrites those
        logits rows and hands the map to the numeric guard (which uses
        ``fixed_by_level`` to decide when the fallback re-decode reads
        finite again)."""
        fired = self._fire("round")
        out: Dict[int, Fault] = {}
        for f in fired:
            if f.kind != "poison_logits":
                continue
            uid = uid_by_slot.get(f.slot)
            if uid is None:
                continue            # victim slot idle: fault is a no-op
            out[f.slot] = f
            self.uids_poisoned.add(uid)
            self._log(f, "round", slot=f.slot, uid=uid,
                      fixed_by_level=f.fixed_by_level)
        return out

    def _crash(self, site: str, kind: str) -> None:
        for f in self._fire(site):
            if f.kind == kind:
                self._log(f, site)
                raise InjectedFault(f"injected {site} crash", kind=kind)

    def on_tokenize(self) -> None:
        self._crash("tokenize", "tokenize_crash")

    def on_detok(self) -> None:
        self._crash("detok", "detok_crash")

    def on_sched(self) -> None:
        """Scheduler-tick hook (one call per scheduler iteration)."""
        self._crash("sched", "sched_crash")
