"""Attention: GQA with RoPE/qk-norm, blockwise (flash-style) training path,
sliding-window variant, and single-token decode against a KV cache.

The blockwise path is the memory-safe formulation for 32k prefill / 4k x 256
training shapes: an outer ``lax.map`` over query blocks and an inner
``lax.scan`` over KV blocks carrying the online-softmax (m, l, acc) state —
O(S * block) live memory instead of O(S^2).

``blockwise_attention`` carries a CUSTOM VJP implementing the true flash
backward (Dao et al.): the forward saves only the per-row logsumexp L and
the output O; the backward recomputes score blocks on the fly and
accumulates dQ/dK/dV blockwise.  Differentiating the naive online-softmax
loop instead makes JAX save every (q_block x kv_block) probability tile —
the baseline dry-run measured those stacked f32 tiles at ~40% of all HBM
traffic on the training cells (EXPERIMENTS.md §Perf iteration 1).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .common import constrain

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B, Sq, nkv, grp, hd), k: (B, Skv, nkv, hd) -> (B, nkv, grp, Sq, Skv)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k)


def _mask_bias(qpos, kpos, causal: bool, window: Optional[int]):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return jnp.where(m, 0.0, NEG_INF)


def dense_attention(q, k, v, *, causal=True, window=None, positions=None):
    """Reference attention. q: (B,S,nh,hd), k/v: (B,S,nkv,hd)."""
    b, sq, nh, hd = q.shape
    nkv = k.shape[2]
    grp = nh // nkv
    qg = q.reshape(b, sq, nkv, grp, hd) * (hd ** -0.5)
    scores = _gqa_scores(qg, k)
    qpos = positions if positions is not None else jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    scores = scores + _mask_bias(qpos, kpos, causal, window)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(b, sq, nh, hd)


def _bias_block(qpos, kpos, causal, window, skv):
    """(qb, kvb) additive mask for one (q_block, kv_block) tile."""
    b = jnp.zeros((qpos.shape[0], kpos.shape[0]), jnp.float32)
    if causal:
        b = jnp.where(qpos[:, None] >= kpos[None, :], b, NEG_INF)
    if window is not None:
        b = jnp.where((qpos[:, None] - kpos[None, :]) < window, b, NEG_INF)
    if skv is not None:
        b = jnp.where(kpos[None, :] < skv, b, NEG_INF)
    return b


def _rep(x, grp):
    return jnp.repeat(x, grp, axis=2) if grp > 1 else x


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_block, kv_block, skv):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block, skv)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block, skv):
    """q pre-scaled (B, Sp, nh, hd); k/v (B, Skp, nkv, hd); Sp/Skp padded.
    Returns (out (B, Sp, nh, hd), lse (B, nh, Sp))."""
    b, sp, nh, hd = q.shape
    skp, nkv = k.shape[1], k.shape[2]
    grp = nh // nkv
    nq, nk = sp // q_block, skp // kv_block
    qg = q.reshape(b, nq, q_block, nh, hd)
    kb = k.reshape(b, nk, kv_block, nkv, hd)
    vb = v.reshape(b, nk, kv_block, nkv, hd)

    def q_step(qi):
        qblk = qg[:, qi]                      # (B, qb, nh, hd)
        qpos = qi * q_block + jnp.arange(q_block)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = _rep(jax.lax.dynamic_index_in_dim(kb, ki, 1, False), grp)
            vblk = _rep(jax.lax.dynamic_index_in_dim(vb, ki, 1, False), grp)
            kpos = ki * kv_block + jnp.arange(kv_block)
            s_blk = jnp.einsum("bqhd,bshd->bhqs", qblk,
                               kblk).astype(jnp.float32)
            s_blk = s_blk + _bias_block(qpos, kpos, causal, window, skv)
            m_new = jnp.maximum(m, s_blk.max(-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p.astype(q.dtype),
                vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, nh, q_block), NEG_INF, jnp.float32),
                jnp.zeros((b, nh, q_block), jnp.float32),
                jnp.zeros((b, nh, q_block, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse                        # (B, nh, qb, hd), (B, nh, qb)

    outs, lses = jax.lax.map(q_step, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, nh, sp, hd)   # (B, nh, Sp, hd)
    out = out.transpose(0, 2, 1, 3).astype(q.dtype)          # (B, Sp, nh, hd)
    lse = jnp.moveaxis(lses, 0, 2).reshape(b, nh, sp)
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_block, kv_block, skv):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block,
                               skv)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_block, kv_block, skv, res, g):
    """True flash backward: recompute score tiles; O(S*block) live memory.

    dS = P * (dP - D),  dP = dO V^T,  D = rowsum(dO * O)
    dQ = dS K,  dK = dS^T Q,  dV = P^T dO
    """
    q, k, v, out, lse = res
    b, sp, nh, hd = q.shape
    skp, nkv = k.shape[1], k.shape[2]
    grp = nh // nkv
    nq, nk = sp // q_block, skp // kv_block
    g = g.astype(q.dtype)
    d_rows = jnp.einsum("bshd,bshd->bhs", g.astype(jnp.float32),
                        out.astype(jnp.float32))             # (B, nh, Sp)
    qg = q.reshape(b, nq, q_block, nh, hd)
    gg = g.reshape(b, nq, q_block, nh, hd)
    kb = k.reshape(b, nk, kv_block, nkv, hd)
    vb = v.reshape(b, nk, kv_block, nkv, hd)
    lse_b = lse.reshape(b, nh, nq, q_block)
    d_b = d_rows.reshape(b, nh, nq, q_block)

    def tile(qi, ki):
        """Recompute (p, ds) for one tile; used by both passes."""
        qblk = qg[:, qi]
        gblk = gg[:, qi]
        kblk = _rep(jax.lax.dynamic_index_in_dim(kb, ki, 1, False), grp)
        vblk = _rep(jax.lax.dynamic_index_in_dim(vb, ki, 1, False), grp)
        qpos = qi * q_block + jnp.arange(q_block)
        kpos = ki * kv_block + jnp.arange(kv_block)
        s_blk = jnp.einsum("bqhd,bshd->bhqs", qblk, kblk).astype(jnp.float32)
        s_blk = s_blk + _bias_block(qpos, kpos, causal, window, skv)
        p = jnp.exp(s_blk - lse_b[:, :, qi][..., None])      # (B,nh,qb,kvb)
        dp = jnp.einsum("bqhd,bshd->bhqs", gblk,
                        vblk).astype(jnp.float32)
        ds = p * (dp - d_b[:, :, qi][..., None])
        return p, ds, qblk, gblk, kblk, vblk

    # SINGLE-PASS sweep (§Perf iteration 6): every (qi, ki) tile is visited
    # exactly once — dK/dV accumulate per outer-ki step while the matching
    # dQ block contributions accumulate into a carried full-dQ buffer.
    # Halves the tile recomputes AND the cross-shard K/V re-gathers of the
    # original two-pass formulation (dq buffer: b*sp*nh_local*hd f32,
    # tens of MB/device at the assigned shapes).
    def kv_outer(dq, ki):
        def q_inner(carry, qi):
            dq, dk, dv = carry
            p, ds, qblk, gblk, kblk, _ = tile(qi, ki)
            dv = dv + jnp.einsum("bhqs,bqhd->bshd", p.astype(q.dtype),
                                 gblk).astype(jnp.float32)
            dk = dk + jnp.einsum("bhqs,bqhd->bshd", ds.astype(q.dtype),
                                 qblk).astype(jnp.float32)
            dq_blk = jnp.einsum("bhqs,bshd->bqhd", ds.astype(q.dtype),
                                kblk).astype(jnp.float32)
            dq = jax.lax.dynamic_update_slice_in_dim(
                dq, jax.lax.dynamic_slice_in_dim(dq, qi * q_block, q_block,
                                                 1) + dq_blk,
                qi * q_block, axis=1)
            return (dq, dk, dv), None
        z = jnp.zeros((b, kv_block, nh, hd), jnp.float32)
        (dq, dk, dv), _ = jax.lax.scan(q_inner, (dq, z, z), jnp.arange(nq))
        if grp > 1:   # fold the repeated heads back onto the KV heads
            dk = dk.reshape(b, kv_block, nkv, grp, hd).sum(3)
            dv = dv.reshape(b, kv_block, nkv, grp, hd).sum(3)
        return dq, (dk, dv)

    dq0 = jnp.zeros((b, sp, nh, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_outer, dq0, jnp.arange(nk))
    dq = dq.astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, skp, nkv, hd).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, skp, nkv, hd).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(q, k, v, *, causal=True, window=None,
                        q_block=512, kv_block=1024, vjp="flash"):
    """Flash-style online-softmax attention, O(S*block) memory in BOTH
    passes (custom VJP — see module docstring).

    q: (B, S, nh, hd); k/v: (B, S, nkv, hd).  GQA is handled by repeating
    the KV heads *per block inside the loop* — every live tensor is then
    plain (..., nh, ...)-major, which keeps SPMD head-sharding clean (a
    grouped (nkv, grp) layout makes GSPMD fall back to "involuntary full
    rematerialization" resharding on the backward pass).

    ``vjp="naive"`` differentiates the forward loop directly (saves the
    probability tiles — the pre-optimization baseline, kept selectable for
    the §Perf A/B).
    """
    b, s, nh, hd = q.shape
    skv = k.shape[1]
    q_block = min(q_block, s)
    kv_block = min(kv_block, skv)
    pq, pk = -s % q_block, -skv % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qs = (q * (hd ** -0.5)).astype(q.dtype)
    if vjp == "naive":
        out = _flash_fwd_impl(qs, k, v, causal, window, q_block, kv_block,
                              skv if pk else None)[0]
    else:
        out = _flash(qs, k, v, causal, window, q_block, kv_block,
                     skv if pk else None)
    return out[:, :s]


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     ring_offset=None):
    """One-token attention against a cache.

    q: (B, 1, nh, hd); k/v_cache: (B, W, nkv, hd); cache_len: count of
    valid entries, scalar (shared) or (B,) per-slot.  ``ring_offset``
    marks the logical start for sliding-window ring buffers.  Returns
    (B, 1, nh, hd).

    Defined as the T=1 case of ``chunk_decode_attention`` so the
    single-token decode path and the speculative verify path stay
    bit-identical BY CONSTRUCTION — the invariant speculative rollback
    correctness rests on.
    """
    b = q.shape[0]
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    return chunk_decode_attention(q, k_cache, v_cache, cl[:, None] - 1)


def chunk_decode_attention(q, k_cache, v_cache, qpos):
    """T-token causal attention against a cache (speculative verify).

    q: (B, T, nh, hd); k/v_cache: (B, W, nkv, hd); qpos: (B, T) absolute
    position of each query token (its K/V row is already in the cache).
    Query t sees cache rows < qpos[b, t] + 1, evaluated per query row, so
    scoring a chunk is bit-identical to scoring its tokens one step at a
    time (rejected-draft rows beyond a query's position mask to exact
    zeros).  ``decode_attention`` is the T=1 case.  Returns (B, T, nh, hd).
    """
    b, w, nkv, hd = k_cache.shape
    t, nh = q.shape[1], q.shape[2]
    grp = nh // nkv
    qg = q.reshape(b, t, nkv, grp, hd) * (hd ** -0.5)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32)
    valid = jnp.arange(w)[None, None, :] < (jnp.asarray(qpos) + 1)[:, :, None]
    scores = jnp.where(valid[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v_cache)
    return out.reshape(b, t, nh, hd)


def decode_attention_packed(q, k_codes, v_codes, cache_len, *, k_scale,
                            v_scale, spec, window=None, ring_offset=None):
    """One-token attention against a posit-packed cache (decode-on-read).

    k/v_codes: (B, W, nkv, Dc) posit codes; k/v_scale: (B, W, nkv) f32
    per-row pow2 scales; ``spec`` a ``core.transprecision.KVStorage``.  On
    accelerators this is the fused Pallas kernel (codes decoded in VMEM
    inside the online-softmax loop — full-precision K/V never touch HBM);
    on CPU, a bit-identical decode + dense reference.  Decoded K/V stay
    f32 so a posit16 cache is strictly more precise than a bf16 one.
    """
    from ..kernels import kv_cache as kv_kernels
    if jax.default_backend() != "cpu":
        return kv_kernels.decode_attention(
            q, k_codes, k_scale, v_codes, v_scale, cache_len,
            spec.fmt, packed=spec.packed)
    k = kv_kernels.decode_kv_rows(k_codes, k_scale[..., None], spec.fmt,
                                  spec.packed)
    v = kv_kernels.decode_kv_rows(v_codes, v_scale[..., None], spec.fmt,
                                  spec.packed)
    return decode_attention(q, k, v, cache_len, window=window,
                            ring_offset=ring_offset)
