"""Mixture-of-Experts layer: top-k routing with dense (one-hot) dispatch.

GShard/Switch-style capacity-based dispatch via einsums — fully static
shapes, differentiable, and expert-parallel: the expert axis shards on
"model" (one or more experts per chip) with all-to-all traffic expressed by
XLA from the dispatch/combine einsum shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import constrain, dense_init


def init_moe(key, d_model, d_ff, n_experts, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": dense_init(k1, (d_model, n_experts), dtype=jnp.float32),
        "wi": dense_init(k2, (n_experts, d_model, 2 * d_ff), dtype=dtype),
        "wo": dense_init(k3, (n_experts, d_ff, d_model), dtype=dtype),
    }


def _route(params, tokens, top_k: int, capacity_factor: float):
    """Shared router: returns (gate_k, idx_k, pos, keep, cap, aux).

    ``capacity_factor <= 0`` selects DROPLESS routing (cap = T, the
    worst-case per-expert load): batch-size-invariant outputs, used by the
    serving paths where capacity drops would corrupt generation."""
    t = tokens.shape[0]
    n_exp = params["router"].shape[-1]
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", tokens.astype(jnp.float32), params["router"]))
    gate_k, idx_k = jax.lax.top_k(gates, top_k)               # (T, k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)
    cap = t if capacity_factor <= 0 else max(
        1, int(capacity_factor * top_k * t / n_exp))
    onehot = jax.nn.one_hot(idx_k, n_exp, dtype=jnp.int32)    # (T, k, E)
    flat = onehot.reshape(t * top_k, n_exp)
    pos_in_exp = (jnp.cumsum(flat, axis=0) - flat).reshape(t, top_k, n_exp)
    pos = (pos_in_exp * onehot).sum(-1)                       # (T, k)
    keep = (pos < cap) & (onehot.sum(-1) > 0)
    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = gates.mean(0)
    fe = (onehot.sum(1) > 0).astype(jnp.float32).mean(0)
    aux = n_exp * jnp.sum(me * fe)
    return gate_k, idx_k, pos, keep, cap, aux


def _expert_ffn(params, xe, quantize_w):
    """xe: (E, C, d) -> (E, C, d) gated SwiGLU per expert."""
    wi, wo = params["wi"], params["wo"]
    if quantize_w is not None:
        wi, wo = quantize_w(wi), quantize_w(wo)
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_ffn(params, x, *, top_k: int, capacity_factor: float = 1.25,
            quantize_w=None, dispatch: str = "auto"
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Two dispatch strategies with identical semantics (tested against each
    other):

    * ``einsum``  — GShard dense one-hot dispatch/combine.  O(T*E*C) dispatch
      tensors: fine for small T, catastrophic at 1M-token training cells.
    * ``scatter`` — indexed dispatch: scatter (token-id, gate) into (E, C)
      slot tables, gather tokens into expert batches, scatter-add results
      back.  O(T*k + E*C*d) memory — the production path at scale.

    ``auto`` picks scatter once the dense dispatch tensor would exceed 2^22
    elements.  Tokens over capacity are dropped (standard capacity batching).
    """
    b, s, d = x.shape
    n_exp = params["router"].shape[-1]
    t = b * s
    tokens = x.reshape(t, d)
    gate_k, idx_k, pos, keep, cap, aux = _route(params, tokens, top_k,
                                                capacity_factor)
    if dispatch == "auto":
        dispatch = "einsum" if t * n_exp * cap <= (1 << 22) else "scatter"

    if dispatch == "einsum":
        disp = (jax.nn.one_hot(idx_k, n_exp, dtype=x.dtype)[..., None]
                * jax.nn.one_hot(pos, cap, dtype=x.dtype)[..., None, :]
                * keep[..., None, None].astype(x.dtype))      # (T,k,E,C)
        comb = disp * gate_k[..., None, None].astype(x.dtype)
        disp_t = disp.sum(1)                                  # (T, E, C)
        comb_t = comb.sum(1)
        xe = jnp.einsum("td,tec->ecd", tokens, disp_t)        # (E, C, d)
        xe = constrain(xe, "expert", None, None)
        ye = _expert_ffn(params, xe, quantize_w)
        ye = constrain(ye, "expert", None, None)
        out = jnp.einsum("ecd,tec->td", ye, comb_t)
    else:
        # slot tables: which token fills (e, c), and with what gate weight
        flat_e = idx_k.reshape(-1)                            # (T*k,)
        flat_p = pos.reshape(-1)
        flat_keep = keep.reshape(-1)
        flat_gate = (gate_k.reshape(-1) * flat_keep).astype(jnp.float32)
        tok_ids = jnp.repeat(jnp.arange(t), top_k)
        # dropped entries write to a trash slot (cap index == cap)
        flat_p = jnp.where(flat_keep, flat_p, cap)
        slot_tok = jnp.zeros((n_exp, cap + 1), jnp.int32).at[
            flat_e, flat_p].set(tok_ids, mode="drop")[:, :cap]
        slot_gate = jnp.zeros((n_exp, cap + 1), jnp.float32).at[
            flat_e, flat_p].set(flat_gate, mode="drop")[:, :cap]
        slot_used = jnp.zeros((n_exp, cap + 1), jnp.bool_).at[
            flat_e, flat_p].set(flat_keep, mode="drop")[:, :cap]
        xe = tokens[slot_tok] * slot_used[..., None].astype(x.dtype)
        xe = constrain(xe, "expert", None, None)
        ye = _expert_ffn(params, xe, quantize_w)
        ye = constrain(ye, "expert", None, None)
        contrib = ye * slot_gate[..., None].astype(ye.dtype)
        out = jnp.zeros((t, d), x.dtype).at[
            slot_tok.reshape(-1)].add(
                contrib.reshape(n_exp * cap, d) *
                slot_used.reshape(-1, 1).astype(ye.dtype))
    return out.reshape(b, s, d), aux
