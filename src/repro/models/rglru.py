"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Sequence mode uses ``jax.lax.associative_scan`` over the (a, b) affine
composition — log-depth, TPU-friendly.  Decode is the O(1) single-step
update, which is why the hybrid arch runs ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init
from ..core.quant import maybe_dequant

C_FACTOR = 8.0


def init_rglru(key, width, dtype):
    ks = jax.random.split(key, 3)
    # Lambda init so a^c in [0.9, 0.999] (paper appendix)
    u = jax.random.uniform(ks[0], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / C_FACTOR))  # softplus^-1(-ln u / c)
    return {
        "w_a": dense_init(ks[1], (width, width), dtype=dtype),
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_x": dense_init(ks[2], (width, width), dtype=dtype),
        "b_x": jnp.zeros((width,), jnp.float32),
        "Lambda": lam,
    }


def _gates(params, x):
    # fused gate projection: one einsum, one bwd TP psum (§Perf)
    w_ax = jnp.concatenate([maybe_dequant(params["w_a"]),
                            maybe_dequant(params["w_x"])], axis=-1)
    ri = jnp.einsum("...d,dk->...k", x, w_ax).astype(jnp.float32)
    r_in, i_in = jnp.split(ri, 2, axis=-1)
    r = jax.nn.sigmoid(r_in + params["b_a"])
    i = jax.nn.sigmoid(i_in + params["b_x"])
    log_a = -C_FACTOR * jax.nn.softplus(params["Lambda"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated_x = mult * i * x.astype(jnp.float32)
    return a, gated_x


def rglru(params, x, h0=None):
    """x: (B, S, width) -> (y, h_last). Associative scan over S."""
    a, gx = _gates(params, x)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a2 * a1, a2 * b1 + b2

    if h0 is not None:
        gx = gx.at[:, 0].add(a[:, 0] * h0)
    a_sc, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(params, x1, h):
    """Decode: x1 (B, 1, width), h (B, width) -> (y (B,1,width), h')."""
    a, gx = _gates(params, x1)
    h_new = a[:, 0] * h + gx[:, 0]
    return h_new[:, None].astype(x1.dtype), h_new
