"""Mamba-2 (SSD — state-space duality) layer: chunked matmul form + decode.

Implements the SSD algorithm of arXiv:2405.21060 in its matmul-friendly
chunked form (intra-chunk quadratic attention-like term + inter-chunk state
recurrence), which is the formulation that maps onto the MXU.  Includes the
depthwise causal conv frontend and the single-token recurrent decode step —
O(1) per token, which is why mamba2 runs the ``long_500k`` cell.

Shapes: d_inner = expand * d_model; nh = d_inner / headdim heads; state N.
x/B/C streams follow the mamba2 grouping (ng groups shared across heads).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import constrain, dense_init


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_headdim
    ng, ds = cfg.ssm_groups, cfg.ssm_state
    conv_ch = d_in + 2 * ng * ds
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * ng * ds + nh), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_ch), dtype=dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.zeros((d_in,), jnp.float32),   # gated RMSNorm
        "out_proj": dense_init(ks[2], (d_in, d), dtype=dtype),
    }


def _split_streams(zxbcdt, cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_headdim
    ng, ds = cfg.ssm_groups, cfg.ssm_state
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * ng * ds], axis=-1)
    return z, xBC, dt  # dt: (..., nh)


def _causal_conv(xBC, conv_w, conv_state=None):
    """Depthwise causal conv along S.  xBC: (B, S, C); conv_w: (K, C).
    With ``conv_state`` ((B, K-1, C)) performs the streaming update instead
    and returns (out, new_state)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(k))
        return jax.nn.silu(out)
    window = jnp.concatenate([conv_state, xBC], axis=1)   # (B, K, C), S==1
    out = sum(window[:, i:i + 1] * conv_w[i] for i in range(k))
    return jax.nn.silu(out), window[:, 1:]


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-triangular pairwise sums
    L[i, j] = sum_{j < t <= i} x_t (i >= j)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    dif = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, dif, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """SSD forward (training/prefill).

    x: (b, S, nh, hd); dt: (b, S, nh) (softplus'd, >0); A: (nh,) negative;
    B, C: (b, S, ng, ds); D: (nh,).  Returns (y, final_state (b, nh, hd, ds)).
    """
    b, s, nh, hd = x.shape
    ng, ds = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = nh // ng
    xc = x.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, ng, ds)
    Cc = C.reshape(b, nc, chunk, ng, ds)
    dA = dtc * A  # (b, nc, Q, nh)

    # intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))           # (b,nc,nh,Q,Q)
    scores = jnp.einsum("bcqgn,bcsgn->bcgqs", Cc, Bc)        # (b,nc,ng,Q,Q)
    scores = jnp.repeat(scores, rep, axis=2)                  # (b,nc,nh,Q,Q)
    gated = scores * L
    y_intra = jnp.einsum("bchqs,bcsh,bcshp->bcqhp", gated, dtc, xc)

    # chunk-local final states
    dA_cum = jnp.cumsum(dA, axis=2)                          # (b,nc,Q,nh)
    dA_tot = dA_cum[:, :, -1]                                # (b,nc,nh)
    decay_out = jnp.exp(dA_tot[:, :, None, :] - dA_cum)      # (b,nc,Q,nh)
    Brep = jnp.repeat(Bc, rep, axis=3)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        Brep, decay_out, dtc, xc)            # (b,nc,nh,hd,ds)

    # inter-chunk recurrence (scan over chunks)
    def chunk_scan(carry, inp):
        st_prev = carry
        st_local, tot = inp
        st = st_prev * jnp.exp(tot)[:, :, None, None] + st_local
        return st, st_prev

    init = jnp.zeros((b, nh, hd, ds), jnp.float32)
    final, prev_states = jax.lax.scan(
        chunk_scan, init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dA_tot, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (b,nc,nh,hd,ds)

    # inter-chunk contribution
    Crep = jnp.repeat(Cc, rep, axis=3)
    decay_in = jnp.exp(dA_cum)                               # (b,nc,Q,nh)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         Crep, decay_in, prev_states.astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    y = y + x * D[None, None, :, None]
    return y, final


def ssd_decode_step(state, x, dt, A, B, C, D):
    """One-token recurrence. state: (b, nh, hd, ds); x: (b, nh, hd);
    dt: (b, nh); B, C: (b, ng, ds). Returns (y (b, nh, hd), new_state)."""
    nh = x.shape[1]
    ng = B.shape[1]
    rep = nh // ng
    Br = jnp.repeat(B, rep, axis=1)                          # (b, nh, ds)
    Cr = jnp.repeat(C, rep, axis=1)
    da = jnp.exp(dt * A)                                     # (b, nh)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x, Br)
    new_state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cr) + x * D[None, :, None]
    return y.astype(x.dtype), new_state


def mamba2_layer(params, x, cfg, *, conv_state=None, ssm_state=None,
                 quantize_w=None):
    """Full mamba2 block. Train/prefill: conv_state/ssm_state None ->
    (y, (conv_state, ssm_state)).  Decode: S==1 with states provided."""
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_headdim
    ng, ds = cfg.ssm_groups, cfg.ssm_state
    w_in, w_out = params["in_proj"], params["out_proj"]
    if quantize_w is not None:
        w_in, w_out = quantize_w(w_in), quantize_w(w_out)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, w_in)
    z, xBC, dt = _split_streams(zxbcdt, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decode = ssm_state is not None
    if decode:
        xBC, conv_state = _causal_conv(xBC, params["conv_w"], conv_state)
    else:
        xBC = _causal_conv(xBC, params["conv_w"])
    xs, B, C = jnp.split(xBC, [d_in, d_in + ng * ds], axis=-1)
    b, s = xs.shape[0], xs.shape[1]
    xh = xs.reshape(b, s, nh, cfg.ssm_headdim)
    Bh = B.reshape(b, s, ng, ds)
    Ch = C.reshape(b, s, ng, ds)
    if decode:
        y, ssm_state = ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0], A, Bh[:, 0], Ch[:, 0], params["D"])
        y = y[:, None]
    else:
        y, ssm_state = ssd_chunked(xh, dt, A, Bh, Ch, params["D"],
                                   min(cfg.ssm_chunk, s))
    y = y.reshape(b, s, d_in)
    # gated RMSNorm (norm(y) * silu(z)) then out projection
    from .common import rms_norm
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"])
    out = jnp.einsum("bsk,kd->bsd", y, w_out)
    return out, (conv_state, ssm_state)


def init_mamba2_state(cfg, batch, dtype=jnp.float32):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_headdim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return (jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), dtype),
            jnp.zeros((batch, nh, cfg.ssm_headdim, cfg.ssm_state), jnp.float32))
