from . import attention, common, lm, moe, rglru, serve_model, ssm
from .lm import ModelCfg, forward, init_params, loss_fn
from .serve_model import decode_step, init_cache, prefill
