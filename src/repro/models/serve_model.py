"""Serving path: cache init, prefill, single-token decode for every family.

Caches are plain pytrees, stacked over pattern periods so decode scans over
layers exactly like training does (HLO size independent of depth):

  attn : {"k","v"}  (P, B, W, nkv, hd)   W = min(window or max_len, max_len)
  rec  : {"h"} (P, B, d), {"conv"} (P, B, K-1, d)
  ssm  : {"state"} (P, B, nh, hd, ds), {"conv"} (P, B, K-1, conv_ch)
  audio adds per-layer cross K/V over the encoder memory.

Attention writes are ring-buffered (idx = pos mod W) so sliding-window archs
(recurrentgemma) keep O(window) memory during ``long_500k`` decode while the
full-attention archs use W = max_len.  With ``policy.kv_layout == "paged"``
the per-slot rings are replaced by a shared page pool + per-sequence page
tables (``kernels/paged_kv.py``; ``cache["page_table"]`` (B, Pmax), flat
pools (R, nkv, Dc) per layer, per-slot vector ``pos``) so HBM tracks live
tokens.  ``cache["pos"]`` may be a scalar (legacy shared position) or a
(B,) per-slot vector — rope, ring/page writes and attention masks all
accept both.  The distributed decode-attention (KV-sequence sharding +
LSE combine) lives in ``repro/serve/distributed.py`` — this module is the
per-shard math it wraps.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.quant import maybe_dequant
from ..core.transprecision import BF16, KVStorage, TCPolicy, kv_storage
from ..kernels import kv_cache as kv_kernels
from ..kernels import paged_kv as paged_kernels
from . import attention, rglru as rglru_mod, ssm as ssm_mod
from .common import apply_rope, rms_norm
from .lm import ModelCfg, _mlp, _qkv, _qw, _rope_cs, forward


def _attn_w(cfg: ModelCfg, max_len: int) -> int:
    if cfg.window:
        return min(cfg.window, max_len)
    return max_len


def _kv_spec(policy: TCPolicy) -> Optional[KVStorage]:
    """Resolved KV-cache storage for ``policy`` (None = model dtype)."""
    return kv_storage(policy)


def _kv_layout(policy: TCPolicy) -> str:
    layout = getattr(policy, "kv_layout", "ring")
    if layout not in ("ring", "paged"):
        raise ValueError(f"unknown kv_layout {layout!r}; known: ring|paged")
    return layout


def init_cache(cfg: ModelCfg, batch: int, max_len: int,
               dtype=None, policy: TCPolicy = BF16, *,
               num_pages: Optional[int] = None) -> Dict[str, Any]:
    """Empty decode state for a batch of sequences up to max_len tokens.

    With a posit ``kv_format`` (or legacy ``packed_kv``) the attention K/V
    rings hold posit CODES plus per-row f32 pow2 scales (``k_scale`` /
    ``v_scale``, shape (B, W, nkv)) — the decode-on-read datapath;
    recurrent/SSM states stay full precision (rewritten every step).

    With ``policy.kv_layout == "paged"`` the per-slot rings are replaced
    by a shared flat page pool (R = num_pages * kv_page_size rows, no
    batch axis) plus a top-level ``page_table`` (B, Pmax) and per-slot
    vector ``pos``.  ``num_pages=None`` fully reserves (1 trash page +
    batch * Pmax) and installs the identity table, so standalone
    prefill/decode works without an allocator; an engine passes its own
    (smaller) pool size and manages the table itself."""
    spec = _kv_spec(policy)
    posit_kv = spec is not None and spec.is_posit
    paged = _kv_layout(policy) == "paged"
    if posit_kv:
        dt = dtype or cfg.dtype            # cross-K/V, memory stay float
        kv_ch = kv_kernels.code_channels(cfg.head_dim, spec.fmt, spec.packed)
    else:
        dt = dtype or (spec.dtype if spec is not None else cfg.dtype)
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    w = _attn_w(cfg, max_len)
    if paged:
        if cfg.window:
            raise ValueError("paged KV layout does not support sliding-"
                             "window attention; use kv_layout='ring'")
        ps = policy.kv_page_size
        pmax = -(-max_len // ps)           # logical pages per slot
        full_pool = num_pages is None
        if full_pool:
            num_pages = 1 + batch * pmax   # page 0 is the trash page
        pool_rows = num_pages * ps
    d_in = cfg.ssm_expand * cfg.d_model
    nh_ssm = d_in // cfg.ssm_headdim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state

    def block_cache(btype: str, stacked: int):
        def z(shape, dtype=dt):
            s = (stacked,) + shape if stacked else shape
            return jnp.zeros(s, dtype)
        if btype == "attn":
            if paged:
                kv_dt = spec.fmt.storage_dtype if posit_kv else dt
                c = {"k": z((pool_rows, nkv, kv_ch if posit_kv else hd),
                            kv_dt),
                     "v": z((pool_rows, nkv, kv_ch if posit_kv else hd),
                            kv_dt)}
                if posit_kv:
                    c["k_scale"] = z((pool_rows, nkv), jnp.float32) + 1.0
                    c["v_scale"] = z((pool_rows, nkv), jnp.float32) + 1.0
            elif posit_kv:
                c = {"k": z((batch, w, nkv, kv_ch), spec.fmt.storage_dtype),
                     "v": z((batch, w, nkv, kv_ch), spec.fmt.storage_dtype),
                     "k_scale": z((batch, w, nkv), jnp.float32) + 1.0,
                     "v_scale": z((batch, w, nkv), jnp.float32) + 1.0}
            else:
                c = {"k": z((batch, w, nkv, hd)), "v": z((batch, w, nkv, hd))}
            if cfg.family == "audio":
                # cross K/V stay unpacked (written once at prefill)
                c["xk"] = z((batch, cfg.enc_seq, nkv, hd), cfg.dtype)
                c["xv"] = z((batch, cfg.enc_seq, nkv, hd), cfg.dtype)
            return c
        if btype == "rec":
            return {"h": z((batch, cfg.d_model), jnp.float32),
                    "conv": z((batch, cfg.conv_kernel - 1, cfg.d_model),
                              cfg.dtype)}
        if btype == "ssm":
            return {"state": z((batch, nh_ssm, cfg.ssm_headdim, cfg.ssm_state),
                               jnp.float32),
                    "conv": z((batch, cfg.conv_kernel - 1, conv_ch),
                              cfg.dtype)}
        raise ValueError(btype)

    cache: Dict[str, Any] = {
        # paged serving needs true per-slot positions; ring keeps the
        # legacy scalar for existing single-sequence callers (both shapes
        # are supported throughout the decode path)
        "pos": jnp.zeros((batch,) if paged else (), jnp.int32),
        "blocks": tuple(block_cache(t, cfg.n_periods) for t in cfg.period),
    }
    if paged:
        if full_pool:   # identity table: slot i owns pages 1+i*pmax ..
            table = 1 + jnp.arange(batch * pmax, dtype=jnp.int32).reshape(
                batch, pmax)
        else:           # caller (engine/allocator) manages the table
            table = jnp.zeros((batch, pmax), jnp.int32)
        cache["page_table"] = table
    if cfg.n_tail:
        tail_types = cfg.block_types[cfg.n_periods * len(cfg.period):]
        cache["tail"] = tuple(block_cache(t, 0) for t in tail_types)
    if cfg.family == "audio":
        cache["memory"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dt)
    return cache


# ---------------------------------------------------------------------------
# Per-block decode steps
# ---------------------------------------------------------------------------

def _ring_write(buf, val, pos):
    """buf: (B, W, ...); val: (B, 1, ...); write at pos mod W.
    ``pos`` scalar (shared) or (B,) per-slot."""
    w = buf.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim:
        return buf.at[jnp.arange(buf.shape[0]), pos % w].set(
            val[:, 0].astype(buf.dtype))
    return jax.lax.dynamic_update_slice_in_dim(buf, val.astype(buf.dtype),
                                               pos % w, axis=1)


def _ring_append_packed(c, kp, vp, pos, spec: KVStorage):
    """Encode-on-write ring append for a posit-packed cache block.

    Pallas ``kv_append`` on accelerators; bit-identical pure-jnp reference
    on CPU (the kernel's interpret-mode overhead is per-layer-per-step)."""
    args = (c["k"], c["k_scale"], c["v"], c["v_scale"],
            kp.astype(jnp.float32), vp.astype(jnp.float32), pos)
    if jax.default_backend() == "cpu":
        return kv_kernels.kv_append_ref(*args, spec.fmt, spec.packed)
    return kv_kernels.kv_append(*args, spec.fmt, packed=spec.packed)


def _paged_append_packed(c, kp, vp, dst, spec: KVStorage):
    """Encode-on-write append into the paged pool (Pallas on accelerators,
    bit-identical pure-jnp reference on CPU)."""
    args = (c["k"], c["k_scale"], c["v"], c["v_scale"],
            kp.astype(jnp.float32), vp.astype(jnp.float32), dst)
    if jax.default_backend() == "cpu":
        return paged_kernels.paged_kv_append_ref(*args, spec.fmt, spec.packed)
    return paged_kernels.paged_kv_append(*args, spec.fmt, packed=spec.packed)


def _attn_decode_paged(c, cfg, policy, pos, qp, kp, vp, table, attn_impl):
    """Paged-pool K/V append + page-walking attention for one layer.

    ``pos`` must be a (B,) per-slot vector; ``c["k"]``/``c["v"]`` are flat
    pools (R, nkv, Dc|hd) shared by all slots; ``table`` is the top-level
    (B, Pmax) page table (shared across layers, closed over by the layer
    scan)."""
    spec = _kv_spec(policy)
    posit_kv = spec is not None and spec.is_posit
    ps = policy.kv_page_size
    dst = paged_kernels.flat_dst_rows(table, pos, ps)
    seq_lens = pos + 1
    new_c = {}
    if posit_kv:
        kc, ks, vc, vs = _paged_append_packed(c, kp, vp, dst, spec)
        if attn_impl is not None and getattr(attn_impl, "paged_kv", False):
            # paged protocol: pool codes + scales + the page table cross
            # the impl boundary (the distributed path ships all three)
            ao = attn_impl(qp, kc, vc, seq_lens, k_scale=ks, v_scale=vs,
                           kv_spec=spec, page_table=table, page_size=ps)
        elif attn_impl is not None:
            k_read = paged_kernels.gather_decode_pages(
                kc, ks, table, ps, spec.fmt, spec.packed)
            v_read = paged_kernels.gather_decode_pages(
                vc, vs, table, ps, spec.fmt, spec.packed)
            ao = attn_impl(qp, k_read, v_read, seq_lens)
        elif jax.default_backend() == "cpu":
            ao = paged_kernels.paged_decode_attention_ref(
                qp, kc, ks, vc, vs, table, seq_lens, spec.fmt,
                page_size=ps, packed=spec.packed)
        else:
            ao = paged_kernels.paged_decode_attention(
                qp, kc, ks, vc, vs, table, seq_lens, spec.fmt,
                page_size=ps, packed=spec.packed)
        new_c.update(k=kc, v=vc, k_scale=ks, v_scale=vs)
    else:
        kc = c["k"].at[dst].set(kp[:, 0].astype(c["k"].dtype))
        vc = c["v"].at[dst].set(vp[:, 0].astype(c["v"].dtype))
        k_read = paged_kernels.gather_pages(kc, table, ps)
        v_read = paged_kernels.gather_pages(vc, table, ps)
        attn_fn = attn_impl or attention.decode_attention
        ao = attn_fn(qp, k_read, v_read, seq_lens)
        new_c.update(k=kc, v=vc)
    return ao, new_c


def _attn_decode(p, c, x, cfg, policy, pos, memory=None, attn_impl=None,
                 page_table=None):
    b = x.shape[0]
    spec = _kv_spec(policy)
    posit_kv = spec is not None and spec.is_posit
    paged = page_table is not None
    pos = jnp.asarray(pos)
    if paged and pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    h = rms_norm(x, p["ln"])
    qp, kp, vp = _qkv(p, h, cfg, policy)
    if pos.ndim:                       # per-slot positions: (B, 1) rope
        posv = pos[:, None]
    else:
        posv = jnp.full((b, 1), pos) if cfg.mrope else pos[None]
    cos, sin = _rope_cs(cfg, posv)
    qp = apply_rope(qp, cos, sin)
    kp = apply_rope(kp, cos, sin)
    new_c = dict(c)
    if paged:
        ao, nc = _attn_decode_paged(c, cfg, policy, pos, qp, kp, vp,
                                    page_table, attn_impl)
        new_c.update(nc)
    elif posit_kv:
        kc, ks, vc, vs = _ring_append_packed(c, kp, vp, pos, spec)
        w = kc.shape[1]
        cl = jnp.minimum(pos + 1, w)
        if attn_impl is not None and getattr(attn_impl, "packed_kv", False):
            # packed protocol: codes + scales cross the impl boundary
            ao = attn_impl(qp, kc, vc, cl, k_scale=ks, v_scale=vs,
                           kv_spec=spec)
        elif attn_impl is not None:
            k_read = kv_kernels.decode_kv_rows(kc, ks[..., None], spec.fmt,
                                               spec.packed)
            v_read = kv_kernels.decode_kv_rows(vc, vs[..., None], spec.fmt,
                                               spec.packed)
            ao = attn_impl(qp, k_read, v_read, cl)
        else:
            ao = attention.decode_attention_packed(
                qp, kc, vc, cl, k_scale=ks, v_scale=vs, spec=spec)
        new_c.update(k=kc, v=vc, k_scale=ks, v_scale=vs)
    else:
        k_cache = _ring_write(c["k"], kp, pos)
        v_cache = _ring_write(c["v"], vp, pos)
        w = k_cache.shape[1]
        attn_fn = attn_impl or attention.decode_attention
        ao = attn_fn(qp, k_cache, v_cache, jnp.minimum(pos + 1, w))
        new_c["k"], new_c["v"] = k_cache, v_cache
    # attention may run at higher precision than the stream (f32-decoded
    # K/V); the residual stream keeps the model dtype for the scan carry
    x = x + jnp.einsum("bsk,kd->bsd", ao.reshape(b, 1, -1),
                       _qw(policy, "attn_weights")(p["wo"])).astype(x.dtype)
    if memory is not None:
        hx = rms_norm(x, p["ln_x"])
        qx = jnp.einsum("bsd,dk->bsk", hx, maybe_dequant(p["wq_x"])).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)
        xo = attention.decode_attention(qx, c["xk"], c["xv"], c["xk"].shape[1])
        x = x + jnp.einsum("bsk,kd->bsd", xo.reshape(b, 1, -1), maybe_dequant(p["wo_x"]))
    h2 = rms_norm(x, p["ln2"])
    if cfg.family == "moe":
        from . import moe as moe_mod
        mo, _ = moe_mod.moe_ffn(p["moe"], h2, top_k=cfg.moe_topk,
                                capacity_factor=cfg.capacity_factor,
                                quantize_w=_qw(policy, "mlp_weights"))
    else:
        mo = _mlp(p, h2, cfg, policy)
    return x + mo, new_c


def _rec_decode(p, c, x, cfg, policy):
    b = x.shape[0]
    h = rms_norm(x, p["ln"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dk->bsk", h, maybe_dequant(p["wy"])))
    u = jnp.einsum("bsd,dk->bsk", h, maybe_dequant(p["wx"]))
    window = jnp.concatenate([c["conv"], u.astype(c["conv"].dtype)], axis=1)
    k = cfg.conv_kernel
    u = sum(window[:, i:i + 1] * p["conv_w"][i] for i in range(k))
    y, h_new = rglru_mod.rglru_step(p["rglru"], u, c["h"])
    x = x + jnp.einsum("bsk,kd->bsd", y * gate, maybe_dequant(p["w_out"]))
    x = x + _mlp(p, rms_norm(x, p["ln2"]), cfg, policy)
    return x, {"h": h_new, "conv": window[:, 1:]}


def _ssm_decode(p, c, x, cfg, policy):
    h = rms_norm(x, p["ln"])
    y, (conv_state, ssm_state) = ssm_mod.mamba2_layer(
        p, h, cfg, conv_state=c["conv"], ssm_state=c["state"],
        quantize_w=_qw(policy, "mlp_weights"))
    return x + y, {"state": ssm_state, "conv": conv_state}


def _block_decode(btype, p, c, x, cfg, policy, pos, memory=None,
                  attn_impl=None, page_table=None):
    if btype == "attn":
        return _attn_decode(p, c, x, cfg, policy, pos, memory=memory,
                            attn_impl=attn_impl, page_table=page_table)
    if btype == "rec":
        return _rec_decode(p, c, x, cfg, policy)
    if btype == "ssm":
        return _ssm_decode(p, c, x, cfg, policy)
    raise ValueError(btype)


def decode_step(params, cache, tokens, cfg: ModelCfg,
                policy: TCPolicy = BF16,
                embeds: Optional[jax.Array] = None,
                attn_impl=None):
    """One serving step. tokens: (B, 1) int32 (or embeds (B, 1, d) for vlm).
    Returns (logits (B, vocab_pad), new_cache)."""
    pos = cache["pos"]
    page_table = cache.get("page_table")
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
    else:
        emb = policy.quantize_weight(params["embed"], "embed_weights")
        x = emb[tokens].astype(cfg.dtype)
    memory = cache.get("memory") if cfg.family == "audio" else None

    def scan_body(carry, pc):
        x = carry
        pparams, pcache = pc
        new_caches = []
        for i, btype in enumerate(cfg.period):
            x, nc = _block_decode(btype, pparams[i], pcache[i], x, cfg,
                                  policy, pos, memory=memory,
                                  attn_impl=attn_impl,
                                  page_table=page_table)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(scan_body, x,
                                 (params["blocks"], cache["blocks"]))
    new_cache = dict(cache)
    new_cache["blocks"] = new_blocks
    if cfg.n_tail:
        tail_types = cfg.block_types[cfg.n_periods * len(cfg.period):]
        new_tail = []
        for p_i, c_i, btype in zip(params["tail"], cache["tail"], tail_types):
            x, nc = _block_decode(btype, p_i, c_i, x, cfg, policy, pos,
                                  memory=memory, attn_impl=attn_impl,
                                  page_table=page_table)
            new_tail.append(nc)
        new_cache["tail"] = tuple(new_tail)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))[:, 0]
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# verify_step: multi-token chunk decode (speculative verify)
# ---------------------------------------------------------------------------

def _ring_write_rows(buf, val, pos):
    """buf: (B, W, ...); val: (B, T, ...); row t of slot b lands at
    (pos[b] + t) mod W.  ``pos`` is the (B,) per-slot start position."""
    b, w = buf.shape[:2]
    t = val.shape[1]
    idx = (jnp.asarray(pos, jnp.int32)[:, None]
           + jnp.arange(t, dtype=jnp.int32)[None, :]) % w
    return buf.at[jnp.arange(b)[:, None], idx].set(val.astype(buf.dtype))


def _ring_append_rows_packed(c, kp, vp, pos, spec: KVStorage):
    """Chunked encode-on-write ring append (Pallas on accelerators,
    bit-identical pure-jnp reference on CPU)."""
    args = (c["k"], c["k_scale"], c["v"], c["v_scale"],
            kp.astype(jnp.float32), vp.astype(jnp.float32), pos)
    if jax.default_backend() == "cpu":
        return kv_kernels.kv_append_rows_ref(*args, spec.fmt, spec.packed)
    return kv_kernels.kv_append_rows(*args, spec.fmt, packed=spec.packed)


def _paged_append_rows_packed(c, kp, vp, dst, spec: KVStorage):
    """Chunked encode-on-write append into the paged pool."""
    args = (c["k"], c["k_scale"], c["v"], c["v_scale"],
            kp.astype(jnp.float32), vp.astype(jnp.float32), dst)
    if jax.default_backend() == "cpu":
        return paged_kernels.paged_kv_append_rows_ref(*args, spec.fmt,
                                                      spec.packed)
    return paged_kernels.paged_kv_append_rows(*args, spec.fmt,
                                              packed=spec.packed)


def _attn_verify(p, c, x, cfg, policy, pos, page_table=None):
    """One attention layer of the T-token verify pass.

    Appends the chunk's T K/V rows (positions pos..pos+T-1 per slot) to
    the cache, then runs chunked causal attention against it.  Every
    per-token operation reuses the decode-path building blocks on a T
    axis, so the logits (and the cache rows written) are bit-identical to
    feeding the chunk through ``decode_step`` one token at a time on the
    CPU/reference backend (the one CI pins).  On accelerators the
    single-token path reads through the fused Pallas kernels while this
    chunk path reads through gather+decode XLA attention — a different
    summation order; the fused chunk kernel is a ROADMAP follow-on."""
    b, t = x.shape[:2]
    spec = _kv_spec(policy)
    posit_kv = spec is not None and spec.is_posit
    paged = page_table is not None
    pos = jnp.asarray(pos)
    h = rms_norm(x, p["ln"])
    qp, kp, vp = _qkv(p, h, cfg, policy)
    posv = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (B, T)
    cos, sin = _rope_cs(cfg, posv)
    qp = apply_rope(qp, cos, sin)
    kp = apply_rope(kp, cos, sin)
    new_c = dict(c)
    if paged:
        ps = policy.kv_page_size
        dst = paged_kernels.flat_dst_rows_chunk(page_table, pos, t, ps)
        if posit_kv:
            kc, ks, vc, vs = _paged_append_rows_packed(c, kp, vp, dst, spec)
            k_read = paged_kernels.gather_decode_pages(
                kc, ks, page_table, ps, spec.fmt, spec.packed)
            v_read = paged_kernels.gather_decode_pages(
                vc, vs, page_table, ps, spec.fmt, spec.packed)
            new_c.update(k=kc, v=vc, k_scale=ks, v_scale=vs)
        else:
            kc = c["k"].at[dst].set(kp.astype(c["k"].dtype))
            vc = c["v"].at[dst].set(vp.astype(c["v"].dtype))
            k_read = paged_kernels.gather_pages(kc, page_table, ps)
            v_read = paged_kernels.gather_pages(vc, page_table, ps)
            new_c.update(k=kc, v=vc)
    elif posit_kv:
        kc, ks, vc, vs = _ring_append_rows_packed(c, kp, vp, pos, spec)
        k_read = kv_kernels.decode_kv_rows(kc, ks[..., None], spec.fmt,
                                           spec.packed)
        v_read = kv_kernels.decode_kv_rows(vc, vs[..., None], spec.fmt,
                                           spec.packed)
        new_c.update(k=kc, v=vc, k_scale=ks, v_scale=vs)
    else:
        kc = _ring_write_rows(c["k"], kp, pos)
        vc = _ring_write_rows(c["v"], vp, pos)
        k_read, v_read = kc, vc
        new_c.update(k=kc, v=vc)
    ao = attention.chunk_decode_attention(qp, k_read, v_read, posv)
    x = x + jnp.einsum("bsk,kd->bsd", ao.reshape(b, t, -1),
                       _qw(policy, "attn_weights")(p["wo"])).astype(x.dtype)
    h2 = rms_norm(x, p["ln2"])
    return x + _mlp(p, h2, cfg, policy), new_c


def verify_step(params, cache, tokens, cfg: ModelCfg,
                policy: TCPolicy = BF16):
    """Multi-token verify pass: decode a (B, T) token chunk in ONE model
    call with per-slot positions — the target-precision half of
    self-speculative decoding.

    tokens: (B, T) int32 — token t of slot b is scored *and* its K/V row
    written at position cache["pos"][b] + t.  Returns (logits
    (B, T, vocab_pad), new_cache) with ``pos`` advanced by T; the caller
    commits accepted tokens and rolls the cache back past the first
    rejection (``serve/speculative.py``).

    Supports attention-only stacks (every token writes exactly one cache
    row, so rollback is a row rewind); recurrent/SSM/MoE/audio families
    would need state snapshots and are rejected.
    """
    if any(bt != "attn" for bt in cfg.block_types):
        raise ValueError("verify_step supports attention-only stacks; "
                         f"{cfg.name} has blocks {set(cfg.block_types)}")
    if cfg.family == "moe":
        raise ValueError("verify_step does not support MoE stacks (chunked "
                         "dispatch changes capacity routing vs per-token)")
    if cfg.family == "audio":
        raise ValueError("verify_step does not support encoder-decoder "
                         "stacks (no cross-attention in the chunk path)")
    if cfg.window:
        raise ValueError("verify_step does not support sliding-window "
                         "attention (rollback assumes no ring wraparound)")
    b, t = tokens.shape
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), (b,))
    page_table = cache.get("page_table")
    emb = policy.quantize_weight(params["embed"], "embed_weights")
    x = emb[tokens].astype(cfg.dtype)

    def scan_body(carry, pc):
        x = carry
        pparams, pcache = pc
        new_caches = []
        for i, _ in enumerate(cfg.period):
            x, nc = _attn_verify(pparams[i], pcache[i], x, cfg, policy, pos,
                                 page_table=page_table)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(scan_body, x,
                                 (params["blocks"], cache["blocks"]))
    new_cache = dict(cache)
    new_cache["blocks"] = new_blocks
    if cfg.n_tail:
        new_tail = []
        for p_i, c_i in zip(params["tail"], cache["tail"]):
            x, nc = _attn_verify(p_i, c_i, x, cfg, policy, pos,
                                 page_table=page_table)
            new_tail.append(nc)
        new_cache["tail"] = tuple(new_tail)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    new_cache["pos"] = cache["pos"] + t
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params, batch, cfg: ModelCfg, max_len: int,
            policy: TCPolicy = BF16, true_len=None):
    """Run the prompt through the model, returning (last_logits, cache).

    Functionally: forward() for the logits + a second pass's worth of cache
    construction fused into the same stack traversal.

    ``true_len`` (scalar or (B,) int32) enables right-padded *bucketed*
    prefill: ``batch["tokens"]`` is padded to a shared bucket width S and
    only the first ``true_len[b]`` tokens of each row are real.  Padding
    rows are causally masked out of every real row's attention (exact-zero
    contributions, so real logits are bit-identical to an unpadded
    prefill), their K/V rows are written as cache-init values (paged: to
    the trash row), logits come from position ``true_len - 1`` per row,
    and ``cache["pos"]`` is the per-slot ``true_len`` vector.  Only
    attention-only stacks support this (recurrent/SSM carries and MoE
    capacity routing are position-dependent under padding).
    """
    from .lm import _attn_block, _rec_block, _ssm_block  # local reuse
    if cfg.family == "vlm" and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
        b, s = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        emb = policy.quantize_weight(params["embed"], "embed_weights")
        x = emb[tokens].astype(cfg.dtype)
    valid = None
    if true_len is not None:
        if (any(bt != "attn" for bt in cfg.block_types) or cfg.window
                or cfg.family in ("moe", "audio")
                or ("embeds" in batch and cfg.family == "vlm")):
            raise ValueError(
                "bucketed prefill (true_len) needs a decoder-only "
                "attention stack without MoE, sliding windows or "
                f"cross/vision inputs; {cfg.name} is not one")
        true_len = jnp.broadcast_to(
            jnp.asarray(true_len, jnp.int32).reshape(-1), (b,))
        valid = jnp.arange(s, dtype=jnp.int32)[None, :] < true_len[:, None]
    cache = init_cache(cfg, b, max_len, policy=policy)
    spec = _kv_spec(policy)
    posit_kv = spec is not None and spec.is_posit
    paged = _kv_layout(policy) == "paged"
    if paged and s > max_len:
        raise ValueError(f"prompt length {s} exceeds max_len {max_len} "
                         "for the paged KV layout")
    w = _attn_w(cfg, max_len)
    memory = None
    if cfg.family == "audio":
        from .lm import _encode_audio
        memory = _encode_audio(params, batch["frames"], cfg, policy)
        cache["memory"] = memory

    start = max(s - w, 0)
    length = min(s, w)
    ring_idx = (start + jnp.arange(length)) % w
    if paged:
        # per-slot flat pool rows for prompt positions 0..s-1; padding
        # rows (bucketed prefill) land on the trash row 0 instead
        ps = policy.kv_page_size
        tok_idx = jnp.arange(s)
        rows2d = (cache["page_table"][:, tok_idx // ps] * ps
                  + (tok_idx % ps)[None, :])                     # (b, s)
        if valid is not None:
            rows2d = jnp.where(valid, rows2d, 0)
        flat_rows = rows2d.reshape(-1)                           # (b*s,)

    def fill(buf, kv):
        rows = kv[:, start:start + length]
        if valid is not None:   # padding rows hold cache-init zeros
            rows = jnp.where(valid[:, start:start + length, None, None],
                             rows, 0)
        return buf.at[:, ring_idx].set(rows.astype(buf.dtype))

    def fill_paged(nc, c_i, name, kv):
        """Bulk write of the prompt's K/V rows into the page pool."""
        if posit_kv:
            codes, scale = kv_kernels.encode_kv_rows(
                kv.astype(jnp.float32), spec.fmt, spec.packed)
            nc[name] = c_i[name].at[flat_rows].set(
                codes.reshape((b * s,) + codes.shape[2:]).astype(
                    c_i[name].dtype))
            nc[name + "_scale"] = c_i[name + "_scale"].at[flat_rows].set(
                scale[..., 0].reshape(b * s, -1))
        else:
            nc[name] = c_i[name].at[flat_rows].set(
                kv.reshape((b * s,) + kv.shape[2:]).astype(c_i[name].dtype))

    def fill_packed(nc, c_i, name, kv):
        """Bulk encode-on-write of the prompt's K/V rows into the ring."""
        codes, scale = kv_kernels.encode_kv_rows(
            kv[:, start:start + length].astype(jnp.float32),
            spec.fmt, spec.packed)
        if valid is not None:   # padding rows hold cache-init codes/scales
            vm = valid[:, start:start + length, None, None]
            codes = jnp.where(vm, codes, 0)
            scale = jnp.where(vm, scale, 1.0)
        nc[name] = c_i[name].at[:, ring_idx].set(
            codes.astype(c_i[name].dtype))
        nc[name + "_scale"] = c_i[name + "_scale"].at[:, ring_idx].set(
            scale[..., 0])

    def run_block(btype, p_i, c_i, x):
        if btype == "attn":
            h = rms_norm(x, p_i["ln"])
            qp, kp, vp = _qkv(p_i, h, cfg, policy)
            pos = jnp.arange(s)
            cos, sin = _rope_cs(cfg, pos[None, :].repeat(b, 0)) if cfg.mrope \
                else _rope_cs(cfg, pos)
            qp = apply_rope(qp, cos, sin)
            kp = apply_rope(kp, cos, sin)
            ao = attention.blockwise_attention(
                qp, kp, vp, causal=True,
                window=cfg.window if cfg.family == "hybrid" or cfg.window else None,
                q_block=cfg.q_block, kv_block=cfg.kv_block)
            x = x + jnp.einsum("bsk,kd->bsd", ao.reshape(b, s, -1),
                               _qw(policy, "attn_weights")(p_i["wo"]))
            nc = dict(c_i)
            if paged:
                fill_paged(nc, c_i, "k", kp)
                fill_paged(nc, c_i, "v", vp)
            elif posit_kv:
                fill_packed(nc, c_i, "k", kp)
                fill_packed(nc, c_i, "v", vp)
            else:
                nc["k"] = fill(c_i["k"], kp)
                nc["v"] = fill(c_i["v"], vp)
            if memory is not None:
                hx = rms_norm(x, p_i["ln_x"])
                qx = jnp.einsum("bsd,dk->bsk", hx, p_i["wq_x"]).reshape(
                    b, s, cfg.n_heads, cfg.head_dim)
                kx = jnp.einsum("bsd,dk->bsk", memory, p_i["wk_x"]).reshape(
                    b, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
                vx = jnp.einsum("bsd,dk->bsk", memory, p_i["wv_x"]).reshape(
                    b, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
                xo = attention.blockwise_attention(qx, kx, vx, causal=False,
                                                   q_block=cfg.q_block,
                                                   kv_block=cfg.kv_block)
                x = x + jnp.einsum("bsk,kd->bsd", xo.reshape(b, s, -1),
                                   p_i["wo_x"])
                nc["xk"], nc["xv"] = kx.astype(nc["xk"].dtype), vx.astype(nc["xv"].dtype)
            h2 = rms_norm(x, p_i["ln2"])
            if cfg.family == "moe":
                from . import moe as moe_mod
                mo, _ = moe_mod.moe_ffn(p_i["moe"], h2, top_k=cfg.moe_topk,
                                        capacity_factor=cfg.capacity_factor,
                                        quantize_w=_qw(policy, "mlp_weights"))
            else:
                mo = _mlp(p_i, h2, cfg, policy)
            return x + mo, nc
        if btype == "rec":
            # track conv tail (raw u) + final hidden state
            h = rms_norm(x, p_i["ln"])
            u_raw = jnp.einsum("bsd,dk->bsk", h, p_i["wx"])
            x, h_last = _rec_block(p_i, x, cfg, policy)
            k = cfg.conv_kernel
            pad = jnp.pad(u_raw, ((0, 0), (k - 1, 0), (0, 0)))
            return x, {"h": h_last.astype(jnp.float32),
                       "conv": pad[:, -(k - 1):].astype(cfg.dtype)}
        if btype == "ssm":
            h = rms_norm(x, p_i["ln"])
            from .ssm import _split_streams
            w_in = _qw(policy, "mlp_weights")(p_i["in_proj"])
            zxbcdt = jnp.einsum("bsd,dk->bsk", h, w_in)
            _, xBC_raw, _ = _split_streams(zxbcdt, cfg)
            y, (_, ssm_state) = ssm_mod.mamba2_layer(
                p_i, h, cfg, quantize_w=_qw(policy, "mlp_weights"))
            k = cfg.conv_kernel
            pad = jnp.pad(xBC_raw, ((0, 0), (k - 1, 0), (0, 0)))
            return x + y.astype(x.dtype), {
                "state": ssm_state,
                "conv": pad[:, -(k - 1):].astype(cfg.dtype)}
        raise ValueError(btype)

    def scan_body(carry, pc):
        x = carry
        pparams, pcache = pc
        ncs = []
        for i, btype in enumerate(cfg.period):
            x, nc = run_block(btype, pparams[i], pcache[i], x)
            ncs.append(nc)
        return x, tuple(ncs)

    x, new_blocks = jax.lax.scan(scan_body, x,
                                 (params["blocks"], cache["blocks"]))
    cache["blocks"] = new_blocks
    if cfg.n_tail:
        tail_types = cfg.block_types[cfg.n_periods * len(cfg.period):]
        new_tail = []
        for p_i, c_i, btype in zip(params["tail"], cache["tail"], tail_types):
            x, nc = run_block(btype, p_i, c_i, x)
            new_tail.append(nc)
        cache["tail"] = tuple(new_tail)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    x_last = (x[:, -1] if true_len is None
              else x[jnp.arange(b), true_len - 1])
    logits = jnp.einsum("bd,dv->bv", x_last, head.astype(cfg.dtype))
    if true_len is not None:
        cache["pos"] = true_len
    else:
        cache["pos"] = (jnp.full((b,), s, jnp.int32) if paged
                        else jnp.asarray(s, jnp.int32))
    return logits, cache
