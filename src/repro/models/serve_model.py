"""Serving path: cache init, prefill, single-token decode for every family.

Caches are plain pytrees, stacked over pattern periods so decode scans over
layers exactly like training does (HLO size independent of depth):

  attn : {"k","v"}  (P, B, W, nkv, hd)   W = min(window or max_len, max_len)
  rec  : {"h"} (P, B, d), {"conv"} (P, B, K-1, d)
  ssm  : {"state"} (P, B, nh, hd, ds), {"conv"} (P, B, K-1, conv_ch)
  audio adds per-layer cross K/V over the encoder memory.

Attention writes are ring-buffered (idx = pos mod W) so sliding-window archs
(recurrentgemma) keep O(window) memory during ``long_500k`` decode while the
full-attention archs use W = max_len.  The distributed decode-attention
(KV-sequence sharding + LSE combine) lives in ``repro/serve/distributed.py``
— this module is the per-shard math it wraps.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.quant import maybe_dequant
from ..core.transprecision import BF16, TCPolicy
from . import attention, rglru as rglru_mod, ssm as ssm_mod
from .common import apply_rope, rms_norm
from .lm import ModelCfg, _mlp, _qkv, _qw, _rope_cs, forward


def _attn_w(cfg: ModelCfg, max_len: int) -> int:
    if cfg.window:
        return min(cfg.window, max_len)
    return max_len


def _kv_fmt(policy: TCPolicy):
    """Packed-KV posit format if the policy stores the cache as codes."""
    from ..core.formats import PositFormat, get
    if policy is not None and policy.packed_kv and policy.kv_cache:
        f = get(policy.kv_cache)
        if isinstance(f, PositFormat):
            return f
    return None


def init_cache(cfg: ModelCfg, batch: int, max_len: int,
               dtype=None, policy: TCPolicy = BF16) -> Dict[str, Any]:
    """Empty decode state for a batch of sequences up to max_len tokens.

    With ``policy.packed_kv`` the attention K/V rings hold posit CODES
    (uint8/16) — the decode-on-read datapath; recurrent/SSM states stay
    full precision (they are rewritten every step)."""
    fmt = _kv_fmt(policy)
    dt = dtype or (fmt.storage_dtype if fmt is not None else cfg.dtype)
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    w = _attn_w(cfg, max_len)
    d_in = cfg.ssm_expand * cfg.d_model
    nh_ssm = d_in // cfg.ssm_headdim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state

    def block_cache(btype: str, stacked: int):
        def z(shape, dtype=dt):
            s = (stacked,) + shape if stacked else shape
            return jnp.zeros(s, dtype)
        if btype == "attn":
            c = {"k": z((batch, w, nkv, hd)), "v": z((batch, w, nkv, hd))}
            if cfg.family == "audio":
                # cross K/V stay unpacked (written once at prefill)
                c["xk"] = z((batch, cfg.enc_seq, nkv, hd), cfg.dtype)
                c["xv"] = z((batch, cfg.enc_seq, nkv, hd), cfg.dtype)
            return c
        if btype == "rec":
            return {"h": z((batch, cfg.d_model), jnp.float32),
                    "conv": z((batch, cfg.conv_kernel - 1, cfg.d_model),
                              cfg.dtype)}
        if btype == "ssm":
            return {"state": z((batch, nh_ssm, cfg.ssm_headdim, cfg.ssm_state),
                               jnp.float32),
                    "conv": z((batch, cfg.conv_kernel - 1, conv_ch),
                              cfg.dtype)}
        raise ValueError(btype)

    cache: Dict[str, Any] = {
        "pos": jnp.zeros((), jnp.int32),
        "blocks": tuple(block_cache(t, cfg.n_periods) for t in cfg.period),
    }
    if cfg.n_tail:
        tail_types = cfg.block_types[cfg.n_periods * len(cfg.period):]
        cache["tail"] = tuple(block_cache(t, 0) for t in tail_types)
    if cfg.family == "audio":
        cache["memory"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dt)
    return cache


# ---------------------------------------------------------------------------
# Per-block decode steps
# ---------------------------------------------------------------------------

def _ring_write(buf, val, pos, fmt=None):
    """buf: (B, W, ...); val: (B, 1, ...); write at pos mod W.
    With ``fmt`` the buffer holds posit codes: encode-on-write."""
    from ..core import posit
    w = buf.shape[1]
    if fmt is not None:
        val = posit.encode_f32(val.astype(jnp.float32), fmt)
    return jax.lax.dynamic_update_slice_in_dim(buf, val.astype(buf.dtype),
                                               pos % w, axis=1)


def _attn_decode(p, c, x, cfg, policy, pos, memory=None, attn_impl=None):
    from ..core import posit
    b = x.shape[0]
    fmt = _kv_fmt(policy)
    h = rms_norm(x, p["ln"])
    qp, kp, vp = _qkv(p, h, cfg, policy)
    posv = jnp.full((b, 1), pos) if cfg.mrope else pos[None]
    cos, sin = _rope_cs(cfg, posv)
    qp = apply_rope(qp, cos, sin)
    kp = apply_rope(kp, cos, sin)
    k_cache = _ring_write(c["k"], kp, pos, fmt)
    v_cache = _ring_write(c["v"], vp, pos, fmt)
    w = k_cache.shape[1]
    if fmt is not None:   # decode-on-read: HBM carries bits/16 of bf16
        k_read = posit.decode_to_f32(k_cache, fmt).astype(cfg.dtype)
        v_read = posit.decode_to_f32(v_cache, fmt).astype(cfg.dtype)
    else:
        k_read, v_read = k_cache, v_cache
    attn_fn = attn_impl or attention.decode_attention
    ao = attn_fn(qp, k_read, v_read, jnp.minimum(pos + 1, w))
    x = x + jnp.einsum("bsk,kd->bsd", ao.reshape(b, 1, -1),
                       _qw(policy, "attn_weights")(p["wo"]))
    new_c = dict(c)
    new_c["k"], new_c["v"] = k_cache, v_cache
    if memory is not None:
        hx = rms_norm(x, p["ln_x"])
        qx = jnp.einsum("bsd,dk->bsk", hx, maybe_dequant(p["wq_x"])).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)
        xo = attention.decode_attention(qx, c["xk"], c["xv"], c["xk"].shape[1])
        x = x + jnp.einsum("bsk,kd->bsd", xo.reshape(b, 1, -1), maybe_dequant(p["wo_x"]))
    h2 = rms_norm(x, p["ln2"])
    if cfg.family == "moe":
        from . import moe as moe_mod
        mo, _ = moe_mod.moe_ffn(p["moe"], h2, top_k=cfg.moe_topk,
                                capacity_factor=cfg.capacity_factor,
                                quantize_w=_qw(policy, "mlp_weights"))
    else:
        mo = _mlp(p, h2, cfg, policy)
    return x + mo, new_c


def _rec_decode(p, c, x, cfg, policy):
    b = x.shape[0]
    h = rms_norm(x, p["ln"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dk->bsk", h, maybe_dequant(p["wy"])))
    u = jnp.einsum("bsd,dk->bsk", h, maybe_dequant(p["wx"]))
    window = jnp.concatenate([c["conv"], u.astype(c["conv"].dtype)], axis=1)
    k = cfg.conv_kernel
    u = sum(window[:, i:i + 1] * p["conv_w"][i] for i in range(k))
    y, h_new = rglru_mod.rglru_step(p["rglru"], u, c["h"])
    x = x + jnp.einsum("bsk,kd->bsd", y * gate, maybe_dequant(p["w_out"]))
    x = x + _mlp(p, rms_norm(x, p["ln2"]), cfg, policy)
    return x, {"h": h_new, "conv": window[:, 1:]}


def _ssm_decode(p, c, x, cfg, policy):
    h = rms_norm(x, p["ln"])
    y, (conv_state, ssm_state) = ssm_mod.mamba2_layer(
        p, h, cfg, conv_state=c["conv"], ssm_state=c["state"],
        quantize_w=_qw(policy, "mlp_weights"))
    return x + y, {"state": ssm_state, "conv": conv_state}


def _block_decode(btype, p, c, x, cfg, policy, pos, memory=None,
                  attn_impl=None):
    if btype == "attn":
        return _attn_decode(p, c, x, cfg, policy, pos, memory=memory,
                            attn_impl=attn_impl)
    if btype == "rec":
        return _rec_decode(p, c, x, cfg, policy)
    if btype == "ssm":
        return _ssm_decode(p, c, x, cfg, policy)
    raise ValueError(btype)


def decode_step(params, cache, tokens, cfg: ModelCfg,
                policy: TCPolicy = BF16,
                embeds: Optional[jax.Array] = None,
                attn_impl=None):
    """One serving step. tokens: (B, 1) int32 (or embeds (B, 1, d) for vlm).
    Returns (logits (B, vocab_pad), new_cache)."""
    pos = cache["pos"]
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
    else:
        emb = policy.quantize_weight(params["embed"], "embed_weights")
        x = emb[tokens].astype(cfg.dtype)
    memory = cache.get("memory") if cfg.family == "audio" else None

    def scan_body(carry, pc):
        x = carry
        pparams, pcache = pc
        new_caches = []
        for i, btype in enumerate(cfg.period):
            x, nc = _block_decode(btype, pparams[i], pcache[i], x, cfg,
                                  policy, pos, memory=memory,
                                  attn_impl=attn_impl)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(scan_body, x,
                                 (params["blocks"], cache["blocks"]))
    new_cache = dict(cache)
    new_cache["blocks"] = new_blocks
    if cfg.n_tail:
        tail_types = cfg.block_types[cfg.n_periods * len(cfg.period):]
        new_tail = []
        for p_i, c_i, btype in zip(params["tail"], cache["tail"], tail_types):
            x, nc = _block_decode(btype, p_i, c_i, x, cfg, policy, pos,
                                  memory=memory, attn_impl=attn_impl)
            new_tail.append(nc)
        new_cache["tail"] = tuple(new_tail)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))[:, 0]
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params, batch, cfg: ModelCfg, max_len: int,
            policy: TCPolicy = BF16):
    """Run the prompt through the model, returning (last_logits, cache).

    Functionally: forward() for the logits + a second pass's worth of cache
    construction fused into the same stack traversal.
    """
    from .lm import _attn_block, _rec_block, _ssm_block  # local reuse
    if cfg.family == "vlm" and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
        b, s = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        emb = policy.quantize_weight(params["embed"], "embed_weights")
        x = emb[tokens].astype(cfg.dtype)
    cache = init_cache(cfg, b, max_len)
    w = _attn_w(cfg, max_len)
    memory = None
    if cfg.family == "audio":
        from .lm import _encode_audio
        memory = _encode_audio(params, batch["frames"], cfg, policy)
        cache["memory"] = memory

    start = max(s - w, 0)
    length = min(s, w)
    ring_idx = (start + jnp.arange(length)) % w

    def fill(buf, kv):
        return buf.at[:, ring_idx].set(kv[:, start:start + length].astype(buf.dtype))

    def run_block(btype, p_i, c_i, x):
        if btype == "attn":
            h = rms_norm(x, p_i["ln"])
            qp, kp, vp = _qkv(p_i, h, cfg, policy)
            pos = jnp.arange(s)
            cos, sin = _rope_cs(cfg, pos[None, :].repeat(b, 0)) if cfg.mrope \
                else _rope_cs(cfg, pos)
            qp = apply_rope(qp, cos, sin)
            kp = apply_rope(kp, cos, sin)
            ao = attention.blockwise_attention(
                qp, kp, vp, causal=True,
                window=cfg.window if cfg.family == "hybrid" or cfg.window else None,
                q_block=cfg.q_block, kv_block=cfg.kv_block)
            x = x + jnp.einsum("bsk,kd->bsd", ao.reshape(b, s, -1),
                               _qw(policy, "attn_weights")(p_i["wo"]))
            nc = dict(c_i)
            nc["k"] = fill(c_i["k"], kp)
            nc["v"] = fill(c_i["v"], vp)
            if memory is not None:
                hx = rms_norm(x, p_i["ln_x"])
                qx = jnp.einsum("bsd,dk->bsk", hx, p_i["wq_x"]).reshape(
                    b, s, cfg.n_heads, cfg.head_dim)
                kx = jnp.einsum("bsd,dk->bsk", memory, p_i["wk_x"]).reshape(
                    b, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
                vx = jnp.einsum("bsd,dk->bsk", memory, p_i["wv_x"]).reshape(
                    b, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
                xo = attention.blockwise_attention(qx, kx, vx, causal=False,
                                                   q_block=cfg.q_block,
                                                   kv_block=cfg.kv_block)
                x = x + jnp.einsum("bsk,kd->bsd", xo.reshape(b, s, -1),
                                   p_i["wo_x"])
                nc["xk"], nc["xv"] = kx.astype(nc["xk"].dtype), vx.astype(nc["xv"].dtype)
            h2 = rms_norm(x, p_i["ln2"])
            if cfg.family == "moe":
                from . import moe as moe_mod
                mo, _ = moe_mod.moe_ffn(p_i["moe"], h2, top_k=cfg.moe_topk,
                                        capacity_factor=cfg.capacity_factor,
                                        quantize_w=_qw(policy, "mlp_weights"))
            else:
                mo = _mlp(p_i, h2, cfg, policy)
            return x + mo, nc
        if btype == "rec":
            # track conv tail (raw u) + final hidden state
            h = rms_norm(x, p_i["ln"])
            u_raw = jnp.einsum("bsd,dk->bsk", h, p_i["wx"])
            x, h_last = _rec_block(p_i, x, cfg, policy)
            k = cfg.conv_kernel
            pad = jnp.pad(u_raw, ((0, 0), (k - 1, 0), (0, 0)))
            return x, {"h": h_last.astype(jnp.float32),
                       "conv": pad[:, -(k - 1):].astype(cfg.dtype)}
        if btype == "ssm":
            h = rms_norm(x, p_i["ln"])
            from .ssm import _split_streams
            w_in = _qw(policy, "mlp_weights")(p_i["in_proj"])
            zxbcdt = jnp.einsum("bsd,dk->bsk", h, w_in)
            _, xBC_raw, _ = _split_streams(zxbcdt, cfg)
            y, (_, ssm_state) = ssm_mod.mamba2_layer(
                p_i, h, cfg, quantize_w=_qw(policy, "mlp_weights"))
            k = cfg.conv_kernel
            pad = jnp.pad(xBC_raw, ((0, 0), (k - 1, 0), (0, 0)))
            return x + y.astype(x.dtype), {
                "state": ssm_state,
                "conv": pad[:, -(k - 1):].astype(cfg.dtype)}
        raise ValueError(btype)

    def scan_body(carry, pc):
        x = carry
        pparams, pcache = pc
        ncs = []
        for i, btype in enumerate(cfg.period):
            x, nc = run_block(btype, pparams[i], pcache[i], x)
            ncs.append(nc)
        return x, tuple(ncs)

    x, new_blocks = jax.lax.scan(scan_body, x,
                                 (params["blocks"], cache["blocks"]))
    cache["blocks"] = new_blocks
    if cfg.n_tail:
        tail_types = cfg.block_types[cfg.n_periods * len(cfg.period):]
        new_tail = []
        for p_i, c_i, btype in zip(params["tail"], cache["tail"], tail_types):
            x, nc = run_block(btype, p_i, c_i, x)
            new_tail.append(nc)
        cache["tail"] = tuple(new_tail)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(cfg.dtype))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits, cache
