"""Unified language-model definition for every assigned architecture.

One config dataclass + one functional model covering:

  dense / vlm — GQA transformer (RoPE or M-RoPE, optional qk-norm)
  moe         — GQA transformer with top-k MoE FFNs (EP-shardable)
  ssm         — Mamba-2 (SSD) stacks
  hybrid      — Griffin/RecurrentGemma pattern (rec, rec, local-attn)
  audio       — Whisper-style encoder-decoder (conv frontend stubbed)

Layers are stacked and scanned per *pattern period* (compile-time compact:
HLO size is independent of depth); remainder layers run unrolled.  Every
weight matmul passes through the TC policy hook, which is how the paper's
transprecision reconfiguration enters the model.

Params and caches are plain dict pytrees.  ``forward`` is the training/
prefill path; ``decode_step`` is the single-token serving path carrying
KV caches / SSM states / RG-LRU states / conv states as appropriate.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quant import maybe_dequant
from ..core.transprecision import BF16, TCPolicy
from . import attention, moe as moe_mod, rglru as rglru_mod, ssm as ssm_mod
from .common import (constrain, cross_entropy, dense_init, embed_init,
                     mrope_freqs, rms_norm, rope_freqs, apply_rope,
                     sinusoid_positions)


def _round_up(x, m):
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str = "model"
    family: str = "dense"      # dense | vlm | moe | ssm | hybrid | audio
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab: int = 256
    mlp: str = "swiglu"        # swiglu | gelu
    rope_theta: float = 10000.0
    qk_norm: bool = False
    mrope: bool = False
    window: Optional[int] = None         # sliding-window for local attn
    pattern: Tuple[str, ...] = ("attn",)  # cycled block types
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_groups: int = 1
    conv_kernel: int = 4
    # audio (whisper-style enc-dec)
    enc_layers: int = 0
    enc_seq: int = 1500
    # execution
    dtype_name: str = "bfloat16"
    remat: str = "full"        # none | dots | full (full = save block inputs
                               # only; "dots" blows past HBM on MoE/FFN-heavy
                               # configs at the assigned shapes)
    scan_layers: bool = True
    q_block: int = 512
    kv_block: int = 1024
    attn_vjp: str = "flash"    # flash (custom bwd) | naive (autodiff loop)
    tie_embed: bool = False

    # ---- derived ----
    @property
    def dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype_name]

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def vocab_pad(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def block_types(self) -> Tuple[str, ...]:
        if self.family == "ssm":
            base = ("ssm",)
        elif self.family == "hybrid":
            base = self.pattern
        else:
            base = ("attn",)
        reps = (self.n_layers + len(base) - 1) // len(base)
        return (base * reps)[: self.n_layers]

    @property
    def period(self) -> Tuple[str, ...]:
        return self.pattern if self.family == "hybrid" else (self.block_types[0],)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def n_tail(self) -> int:
        return self.n_layers - self.n_periods * len(self.period)

    def param_count(self) -> int:
        p = init_params(jax.random.PRNGKey(0), self, abstract=True)
        return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(p))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ModelCfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 12)
    p = {
        "ln": jnp.zeros((d,), jnp.float32),
        "wq": dense_init(ks[0], (d, nh * hd), dtype=cfg.dtype),
        "wk": dense_init(ks[1], (d, nkv * hd), dtype=cfg.dtype),
        "wv": dense_init(ks[2], (d, nkv * hd), dtype=cfg.dtype),
        "wo": dense_init(ks[3], (nh * hd, d), dtype=cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    if cross:
        p["ln_x"] = jnp.zeros((d,), jnp.float32)
        p["wq_x"] = dense_init(ks[4], (d, nh * hd), dtype=cfg.dtype)
        p["wk_x"] = dense_init(ks[5], (d, nkv * hd), dtype=cfg.dtype)
        p["wv_x"] = dense_init(ks[6], (d, nkv * hd), dtype=cfg.dtype)
        p["wo_x"] = dense_init(ks[7], (nh * hd, d), dtype=cfg.dtype)
    p["ln2"] = jnp.zeros((d,), jnp.float32)
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[8], d, cfg.d_ff, cfg.moe_experts, cfg.dtype)
    else:
        wi_cols = 2 * cfg.d_ff if cfg.mlp == "swiglu" else cfg.d_ff
        p["wi"] = dense_init(ks[9], (d, wi_cols), dtype=cfg.dtype)
        p["wo_mlp"] = dense_init(ks[10], (cfg.d_ff, d), dtype=cfg.dtype)
    return p


def _init_rec_block(key, cfg: ModelCfg):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    wi_cols = 2 * cfg.d_ff if cfg.mlp == "swiglu" else cfg.d_ff
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "wx": dense_init(ks[0], (d, d), dtype=cfg.dtype),
        "wy": dense_init(ks[1], (d, d), dtype=cfg.dtype),
        "conv_w": dense_init(ks[2], (cfg.conv_kernel, d), dtype=cfg.dtype),
        "rglru": rglru_mod.init_rglru(ks[3], d, cfg.dtype),
        "w_out": dense_init(ks[4], (d, d), dtype=cfg.dtype),
        "ln2": jnp.zeros((d,), jnp.float32),
        "wi": dense_init(ks[5], (d, wi_cols), dtype=cfg.dtype),
        "wo_mlp": dense_init(ks[6], (cfg.d_ff, d), dtype=cfg.dtype),
    }


def _init_block(key, cfg: ModelCfg, btype: str, cross=False):
    if btype == "attn":
        return _init_attn_block(key, cfg, cross=cross)
    if btype == "rec":
        return _init_rec_block(key, cfg)
    if btype == "ssm":
        p = ssm_mod.init_mamba2(key, cfg, cfg.dtype)
        p["ln"] = jnp.zeros((cfg.d_model,), jnp.float32)
        return p
    raise ValueError(btype)


def _stack_init(key, cfg: ModelCfg, n: int, types, cross=False):
    """Stack n periods of block params (leading axis = period index)."""
    def one(k):
        ks = jax.random.split(k, len(types))
        return tuple(_init_block(ki, cfg, t, cross=cross) for ki, t in zip(ks, types))
    keys = jax.random.split(key, n)
    return jax.vmap(one)(keys)


def init_params(key, cfg: ModelCfg, abstract: bool = False):
    def build(key):
        ks = jax.random.split(key, 8)
        p: Dict[str, Any] = {
            "embed": embed_init(ks[0], (cfg.vocab_pad, cfg.d_model), cfg.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embed:
            p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_pad),
                                      dtype=cfg.dtype)
        cross = cfg.family == "audio"
        p["blocks"] = _stack_init(ks[2], cfg, cfg.n_periods, cfg.period, cross=cross)
        if cfg.n_tail:
            tail_types = cfg.block_types[cfg.n_periods * len(cfg.period):]
            tks = jax.random.split(ks[3], cfg.n_tail)
            p["tail"] = tuple(_init_block(k, cfg, t, cross=cross)
                              for k, t in zip(tks, tail_types))
        if cfg.family == "audio":
            p["enc_blocks"] = _stack_init(ks[4], cfg, cfg.enc_layers, ("attn",))
            p["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        return p

    if abstract:
        return jax.eval_shape(build, key)
    return build(key)


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------

def _qw(policy: TCPolicy, role):
    def q(w):
        return policy.quantize_weight(w, role)
    return q


def _mlp(p, x, cfg, policy):
    q = _qw(policy, "mlp_weights")
    h = jnp.einsum("bsd,df->bsf", x, q(p["wi"]))
    if cfg.mlp == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, q(p["wo_mlp"]))


def _qkv(p, x, cfg, policy, prefix=""):
    """Fused QKV projection: ONE einsum over concat(wq, wk, wv).

    Structural collective optimization (§Perf "fused projections"): with
    tensor parallelism the backward of each x @ W needs a psum of the
    (b, s, d) cotangent over "model"; three separate projections cost three
    all-reduces per layer, the fused one costs one.  The concat itself is
    weight-sized (recomputed under remat), negligible next to activations.
    """
    q_ = _qw(policy, "attn_weights")
    b, s, d = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    wq, wk, wv = (q_(p[prefix + "wq"]), q_(p[prefix + "wk"]),
                  q_(p[prefix + "wv"]))
    wqkv = jnp.concatenate([wq, wk, wv], axis=-1)
    qkv = jnp.einsum("bsd,dk->bsk", x, wqkv)
    qp, kp, vp = jnp.split(qkv, [nh * hd, (nh + nkv) * hd], axis=-1)
    qp = qp.reshape(b, s, nh, hd)
    kp = kp.reshape(b, s, nkv, hd)
    vp = vp.reshape(b, s, nkv, hd)
    if cfg.qk_norm and not prefix:
        qp = rms_norm(qp, p["q_norm"])
        kp = rms_norm(kp, p["k_norm"])
    return qp, kp, vp


def _rope_cs(cfg, positions, batched=False):
    if cfg.mrope:
        pos3 = jnp.broadcast_to(positions, (3,) + positions.shape) \
            if positions.ndim == 2 else positions
        half = cfg.head_dim // 2
        sec = (half - 2 * ((half // 8) * 3), (half // 8) * 3, (half // 8) * 3)
        return mrope_freqs(cfg.head_dim, cfg.rope_theta, pos3, sections=sec)
    return rope_freqs(cfg.head_dim, cfg.rope_theta, positions)


def _attn_block(p, x, cfg: ModelCfg, policy, *, causal=True, use_rope=True,
                window=None, memory=None):
    """Training/prefill attention block (+MLP). memory: (enc_x) for cross."""
    b, s, _ = x.shape
    h = rms_norm(x, p["ln"])
    qp, kp, vp = _qkv(p, h, cfg, policy)
    if use_rope:
        pos = jnp.arange(s)
        cos, sin = _rope_cs(cfg, pos[None, :].repeat(b, 0)) if cfg.mrope \
            else _rope_cs(cfg, pos)
        qp = apply_rope(qp, cos, sin)
        kp = apply_rope(kp, cos, sin)
    qp = constrain(qp, "batch", None, "heads", None)
    ao = attention.blockwise_attention(qp, kp, vp, causal=causal, window=window,
                                       q_block=cfg.q_block, kv_block=cfg.kv_block,
                                       vjp=cfg.attn_vjp)
    ao = jnp.einsum("bsk,kd->bsd",
                    ao.reshape(b, s, -1), _qw(policy, "attn_weights")(p["wo"]))
    x = x + ao
    if memory is not None:  # cross attention (audio decoder)
        hx = rms_norm(x, p["ln_x"])
        qx = jnp.einsum("bsd,dk->bsk", hx, maybe_dequant(p["wq_x"])).reshape(
            b, s, cfg.n_heads, cfg.head_dim)
        kx = jnp.einsum("bsd,dk->bsk", memory, maybe_dequant(p["wk_x"])).reshape(
            b, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
        vx = jnp.einsum("bsd,dk->bsk", memory, maybe_dequant(p["wv_x"])).reshape(
            b, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
        xo = attention.blockwise_attention(qx, kx, vx, causal=False,
                                           q_block=cfg.q_block,
                                           kv_block=cfg.kv_block,
                                           vjp=cfg.attn_vjp)
        x = x + jnp.einsum("bsk,kd->bsd", xo.reshape(b, s, -1), maybe_dequant(p["wo_x"]))
    h2 = rms_norm(x, p["ln2"])
    if cfg.family == "moe":
        mo, aux = moe_mod.moe_ffn(p["moe"], h2, top_k=cfg.moe_topk,
                                  capacity_factor=cfg.capacity_factor,
                                  quantize_w=_qw(policy, "mlp_weights"))
    else:
        mo, aux = _mlp(p, h2, cfg, policy), 0.0
    return x + mo, aux


def _rec_block(p, x, cfg, policy, *, h0=None, conv_state=None):
    """Griffin recurrent block (+MLP). Sequence mode (decode via _rec_step).
    wx/wy fused into one einsum (one bwd psum instead of two — §Perf)."""
    h = rms_norm(x, p["ln"])
    wxy = jnp.concatenate([maybe_dequant(p["wy"]), maybe_dequant(p["wx"])],
                          axis=-1)
    yu = jnp.einsum("bsd,dk->bsk", h, wxy)
    gate_in, u = jnp.split(yu, 2, axis=-1)
    gate = jax.nn.gelu(gate_in)
    k = cfg.conv_kernel
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    u = sum(pad[:, i:i + u.shape[1]] * p["conv_w"][i] for i in range(k))
    y, h_last = rglru_mod.rglru(p["rglru"], u, h0=h0)
    out = jnp.einsum("bsk,kd->bsd", y * gate, maybe_dequant(p["w_out"]))
    x = x + out
    x = x + _mlp(p, rms_norm(x, p["ln2"]), cfg, policy)
    return x, h_last


def _ssm_block(p, x, cfg, policy, states=None):
    h = rms_norm(x, p["ln"])
    conv_state, ssm_state = states if states is not None else (None, None)
    y, new_states = ssm_mod.mamba2_layer(
        p, h, cfg, conv_state=conv_state, ssm_state=ssm_state,
        quantize_w=_qw(policy, "mlp_weights"))
    return x + y.astype(x.dtype), new_states


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------

def _block_fwd(btype: str, p, x, cfg, policy, memory=None):
    if btype == "attn":
        window = cfg.window if (cfg.family == "hybrid" or cfg.window) else None
        return _attn_block(p, x, cfg, policy, causal=True, window=window,
                           memory=memory)
    if btype == "rec":
        out, _ = _rec_block(p, x, cfg, policy)
        return out, 0.0
    if btype == "ssm":
        out, _ = _ssm_block(p, x, cfg, policy)
        return out, 0.0
    raise ValueError(btype)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _run_stack(blocks, tail, x, cfg, policy, memory=None, causal=True):
    period = cfg.period

    def period_fn(x, pparams):
        # sequence-parallel residual stream: the saved remat residual per
        # period is (b/data, s/model, d) — without this the stacked scan
        # residuals alone exceed HBM at the assigned training shapes
        x = constrain(x, "batch", "seq", None)
        aux = 0.0
        for i, btype in enumerate(period):
            p_i = pparams[i]  # pparams: tuple of per-type dicts (one period)
            if btype == "attn" and not causal:
                x, a = _attn_block(p_i, x, cfg, policy, causal=False,
                                   use_rope=False)
            else:
                x, a = _block_fwd(btype, p_i, x, cfg, policy, memory=memory)
            aux = aux + a
        return x, aux

    period_fn = _remat(period_fn, cfg)

    if cfg.scan_layers:
        def scan_body(carry, pparams):
            x, aux = carry
            x, a = period_fn(x, pparams)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(scan_body, (x, 0.0), blocks)
    else:
        aux = 0.0
        n = jax.tree_util.tree_leaves(blocks)[0].shape[0]
        for i in range(n):
            pparams = jax.tree.map(lambda a: a[i], blocks)
            x, a = period_fn(x, pparams)
            aux = aux + a
    if tail:
        for p_i, btype in zip(tail, cfg.block_types[cfg.n_periods * len(cfg.period):]):
            x, a = _block_fwd(btype, p_i, x, cfg, policy, memory=memory)
            aux = aux + a
    return x, aux


def _encode_audio(params, frames, cfg, policy):
    x = frames.astype(cfg.dtype) + sinusoid_positions(
        frames.shape[1], cfg.d_model).astype(cfg.dtype)
    x, _ = _run_stack(params["enc_blocks"], None, x, cfg, policy, causal=False)
    return rms_norm(x, params["enc_norm"])


def forward(params, batch: Dict[str, jax.Array], cfg: ModelCfg,
            policy: TCPolicy = BF16):
    """Returns (logits (B, S, vocab_pad), aux_loss)."""
    if cfg.family in ("vlm",) and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        tokens = batch["tokens"]
        emb = params["embed"]
        emb_q = policy.quantize_weight(emb, "embed_weights")
        x = emb_q[tokens].astype(cfg.dtype)
    x = constrain(x, "batch", None, None)
    memory = None
    if cfg.family == "audio":
        memory = _encode_audio(params, batch["frames"], cfg, policy)
    x, aux = _run_stack(params["blocks"], params.get("tail"), x, cfg, policy,
                        memory=memory)
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    head = policy.quantize_weight(head, "embed_weights", node="lm_head")
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    logits = constrain(logits, "batch", None, "vocab")
    return logits, aux


def loss_fn(params, batch, cfg: ModelCfg, policy: TCPolicy = BF16):
    logits, aux = forward(params, batch, cfg, policy)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}
