"""Shared model components: norms, embeddings, RoPE/M-RoPE, init, sharding.

All models are pure functional JAX: params are plain dict pytrees, every
layer is a function.  Sharding is expressed through ``constrain`` which
applies ``with_sharding_constraint`` only when the launcher has installed
axis rules (so the same model code runs unsharded on CPU smoke tests and
fully sharded in the multi-pod dry-run).
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical-axis sharding rules
# ---------------------------------------------------------------------------
# logical axes used by the models:
#   "batch"   — global batch            -> ("pod","data") typically
#   "seq"     — sequence                -> None or "model" (SP)
#   "heads"   — attention heads         -> "model" when divisible
#   "kv_seq"  — cache sequence          -> "model" for distributed decode
#   "embed"   — d_model                 -> None (or "data" for 2D FSDP)
#   "ffn"     — d_ff                    -> "model"
#   "vocab"   — vocabulary              -> "model"
#   "expert"  — MoE experts             -> "model"
#   "layers"  — stacked scan dim        -> None
#   "fsdp"    — param shard dim         -> "data"

_RULES: contextvars.ContextVar = contextvars.ContextVar("axis_rules", default=None)


@contextlib.contextmanager
def axis_rules(rules: Optional[dict]):
    """Install logical->mesh axis rules (launcher only)."""
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def logical_to_spec(names: Sequence[Optional[str]]) -> P:
    rules = _RULES.get() or {}
    return P(*[rules.get(n) if n else None for n in names])


def constrain(x, *names: Optional[str]):
    """with_sharding_constraint on logical axes; no-op without rules."""
    rules = _RULES.get()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_spec(names))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, scale, eps=1e-6):
    """RMSNorm with a bf16-cotangent custom VJP.

    Autodiff through the f32-upcast norm makes the whole upstream cotangent
    region f32 — and the TP all-reduces of (B, S, d) activations that land
    inside it go over the wire at 4 B/elem.  The custom VJP computes the
    backward math in f32 but emits dx in x.dtype (bf16), halving those
    collective payloads (§Perf iteration "bf16 cotangents").
    """
    return _rms_fwd(x, scale, eps)[0]


def _rms_fwd(x, scale, eps):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
                        + eps)
    out = x32 * inv * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt), (x, scale)


def _rms_bwd(eps, res, g):
    x, scale = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
                        + eps)
    xhat = x32 * inv
    gs = g32 * (1.0 + scale.astype(jnp.float32))
    dx = inv * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(g32 * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float, positions):
    """positions: (..., S) int -> cos/sin (..., S, d_head/2) f32."""
    half = d_head // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, h, d); cos/sin: (B, S, d/2) or (S, d/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def mrope_freqs(d_head: int, theta: float, positions_3d, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: the head dim splits into (temporal, h, w) sections,
    each rotated by its own position stream.  positions_3d: (3, B, S).

    For the text-only / stub-frontend path all three streams carry the text
    position (the VLM frontend that would supply true (t,h,w) grids is a
    stub per the assignment), which reduces exactly to 1-D RoPE — the
    section plumbing is exercised either way.
    """
    half = d_head // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    coss, sins = [], []
    off = 0
    for i, sec in enumerate(sections):
        pos = positions_3d[i].astype(jnp.float32)  # (B, S)
        ang = pos[..., None] * inv[off:off + sec]
        coss.append(jnp.cos(ang))
        sins.append(jnp.sin(ang))
        off += sec
    return jnp.concatenate(coss, -1), jnp.concatenate(sins, -1)  # (B,S,half)


def sinusoid_positions(seq: int, dim: int):
    """Whisper-style fixed sinusoidal embeddings (S, d)."""
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, jnp.float32)


def cross_entropy(logits, labels, vocab: int):
    """Mean CE over valid labels (label == -1 masked); logits may be padded
    beyond ``vocab`` (padded-vocab sharding) — the pad region is masked."""
    vpad = logits.shape[-1]
    if vpad > vocab:
        neg = jnp.full((vpad - vocab,), -1e9, logits.dtype)
        logits = logits.at[..., vocab:].set(neg)
    valid = labels >= 0
    labels_c = jnp.clip(labels, 0, vocab - 1)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels_c[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
