"""Cycle-level TALU / TALU-V simulator (the paper's §IV-A methodology).

The paper evaluated TALU with "a Python-based cycle-level simulator ... for
estimating the number of cycles for Posit computations" (Table III).  This
module is that simulator, reconstructed:

* every primitive executes *real Q-function micro-ops* (``core.qfunc``), so
  results are bit-accurate (verified against ``posit_ref`` / integer
  semantics in tests);
* cycles follow the paper's datapath rules: a cluster retires one 8-bit
  Q-plane per cycle; ADD/XOR take two planes (carry on PC, sum on SC,
  pipelined across slices); COMP/AND/OR/NOT/decode-compare take one; the
  shifter, LUT and combiner are single-cycle units;
* the exact micro-op *schedules* of the paper (which overlap the two
  clusters) are not published, so per-operation totals are reported both as
  our structural sequential count and alongside the paper's Table III values
  (see ``benchmarks/bench_table3_cycles.py``).  Counts we can derive
  structurally (decode = 2/6, INT add = 2/4, INT4 mul = 13) land exactly.

TALU-V (the 128-lane SIMD vector unit) is modelled by ``VectorUnit``:
cycles for a vector op equal the scalar TALU cycles (all lanes in lockstep),
which is what makes the equi-area throughput comparison of Table VI work.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

from . import posit_ref, qfunc
from .formats import PositFormat

P = 8  # physical Q-block width (paper: p = 8)


def _slices(bits: int) -> int:
    return max(1, math.ceil(bits / P))


@dataclasses.dataclass
class CycleCounter:
    cycles: int = 0

    def tick(self, n: int = 1):
        self.cycles += n


class TALU:
    """One transprecision ALU: two 8-wide Q clusters + shifter/LUT/combiner."""

    def __init__(self):
        self.cc = CycleCounter()

    # ---- primitive ops (cycle-costed, bit-accurate) ----------------------

    def op_and(self, a, b, bits=8):
        self.cc.tick(_slices(bits))
        return qfunc.cluster_and(a, b, p=bits)

    def op_or(self, a, b, bits=8):
        self.cc.tick(_slices(bits))
        return qfunc.cluster_or(a, b, p=bits)

    def op_not(self, b, bits=8):
        self.cc.tick(_slices(bits))
        return qfunc.cluster_not(b, p=bits) & ((1 << bits) - 1)

    def op_comp(self, a, b, bits=8):
        self.cc.tick(_slices(bits))
        return qfunc.q_comp(a, b, bits - 1, p=bits)

    def op_add(self, a, b, bits=8, c0=0):
        # carry plane (PC) + sum plane (SC), per 8-bit slice
        self.cc.tick(2 * _slices(bits))
        s, cout = qfunc.cluster_add(a, b, p=bits, c0=c0)
        return s, cout

    def op_xor(self, a, b, bits=8):
        self.cc.tick(_slices(bits) + 1)
        return qfunc.cluster_xor(a, b, p=bits)

    def op_shift(self, a, k, bits=8, left=True):
        self.cc.tick(1)  # barrel shifter unit
        a = np.asarray(a, np.int64)
        out = (a << k) if left else (a >> k)
        return out & ((1 << bits) - 1)

    def op_lut(self, table, idx):
        self.cc.tick(1)
        return np.asarray(table)[idx]

    # ---- Posit decode (Algorithm 1 on the clusters) -----------------------

    def posit_decode(self, code, fmt: PositFormat) -> Tuple[int, int, int, int, int]:
        """Returns (s, K, E, f_len, F); cycles: 2 for n=8, 6 for n=16.

        n=8:  cycle 1 — seven parallel Q comparisons on the PC (Table I row
              "Posit Decode"); cycle 2 — LUT lookup + shifter.
        n=16: cycle 1 — both clusters compare their half concurrently;
              cycles 2-3 — the two thermometer vectors are looked up
              sequentially; cycle 4 — Combiner joins the regime; cycle 5 —
              shifter exposes E/F; cycle 6 — TRF writeback.  (§III-C: the
              *comparisons* take the same time for 8 and 16 bit; Table III's
              6 cycles include the sequential lookups + combine + writeback.)
        """
        n, es = fmt.bits, fmt.es
        code = int(code) & ((1 << n) - 1)
        s = code >> (n - 1)
        if code in (0, 1 << (n - 1)):
            self.cc.tick(2 if n == 8 else 6)
            return s, 0, 0, 0, 0
        mag = code if s == 0 else ((-code) & ((1 << n) - 1))
        body = mag & ((1 << (n - 1)) - 1)
        lead = (body >> (n - 2)) & 1
        t_val = body if lead else ((~body) & ((1 << (n - 1)) - 1))
        if n == 8:
            v = [int(qfunc.q_posit_decode_compare(t_val, i, p=n)) for i in range(n - 1)]
            self.cc.tick(1)                      # 7 Q blocks in parallel (PC)
            r = int(np.sum(v))
            k = self.op_lut(np.arange(n) - 1, r) if lead else -int(r)
            if not lead:
                self.cc.tick(1)                  # LUT cycle still spent
            # shifter exposes E/F in the same second cycle (§III-C: 2 total)
        else:
            lo, hi = t_val & 0xFF, (t_val >> 8) & 0x7F
            v_hi = [int(qfunc.q_posit_decode_compare(hi, i, p=8)) for i in range(7)]
            v_lo = [int(qfunc.q_posit_decode_compare(lo >> 1, i, p=8)) for i in range(7)]
            self.cc.tick(1)                      # both clusters concurrently
            r_hi = self.op_lut(np.arange(8), int(np.sum(v_hi)))  # sequential
            r_lo = self.op_lut(np.arange(8), int(np.sum(v_lo)))  # lookups
            self.cc.tick(1)                      # combiner
            # (combined run length; functional value from the exact fields)
            r = None
            self.cc.tick(1)                      # shifter
            self.cc.tick(1)                      # TRF writeback
        # functional result (exact, from the reference field extractor)
        s_, K, E, f_len, F = posit_ref.decode_fields(code, n, es)
        return s_, K, E, f_len, F

    # ---- integer multiply: shift-add over Q-op planes ---------------------

    def int_mul(self, a, b, bits=8, charge_bits=None):
        """Sequential shift-add multiply (n iterations of AND + n-bit ADD into
        the accumulator's top half; the shift is wiring).  Bit-accurate; the
        cycle charge follows the reconstruction that lands Table III exactly:

          per-iteration: AND = ceil(n/8), ADD = 2*ceil(n/8)
          final:         carry-resolve 2*ceil(n/8) (n>4) + writeback
                         ceil(2n/8) + control (n>8)

        ``charge_bits`` decouples the charged width from the functional width
        (TALU's posit path multiplies mantissas on a fixed 4-bit micro-
        multiplier per Table III — see bench_table3 derivation).
        """
        a, b = int(a), int(b)
        cb = charge_bits or bits
        sl = _slices(cb)
        acc = 0
        for i in range(bits):
            row = qfunc.cluster_and(a, -((b >> i) & 1) & ((1 << bits) - 1), p=bits)
            acc = acc + (int(row) << i)
        for _ in range(cb):
            self.cc.tick(sl + 2 * sl)           # AND + acc ADD per iteration
        self.cc.tick((2 * sl if cb > 4 else 0)  # final carry resolve
                     + _slices(2 * cb)          # product writeback
                     + (1 if cb > 8 else 0))    # control
        assert acc == a * b, (a, b, acc)
        return acc

    def int_add(self, a, b, bits=8):
        s, cout = self.op_add(int(a) & ((1 << bits) - 1), int(b) & ((1 << bits) - 1), bits=bits)
        assert s == ((int(a) + int(b)) & ((1 << bits) - 1))
        return s, cout

    # ---- posit arithmetic programs ----------------------------------------

    def posit_mul(self, a, b, fmt: PositFormat) -> int:
        """Posit multiply as a TALU micro-op program. Bit-accurate vs oracle."""
        n, es = fmt.bits, fmt.es
        nar = posit_ref.nar_code(n)
        if a in (0, nar) or b in (0, nar):
            self.cc.tick(2 if n == 8 else 6)  # decode detects specials
            return nar if (a == nar or b == nar) else 0
        # Pair decode: n=8 -> 2 cycles (one operand per cluster, §III-C);
        # n=16 -> 12 cycles (each 16-bit decode consumes BOTH clusters for 6
        # cycles, so two operands decode sequentially — this is the unique
        # reconstruction consistent with all four posit rows of Table III).
        sa, Ka, Ea, fla, Fa = posit_ref.decode_fields(a, n, es)
        sb, Kb, Eb, flb, Fb = posit_ref.decode_fields(b, n, es)
        self.cc.tick(2 if n == 8 else 12)
        # mantissa multiply on the fixed 4-bit micro-multiplier (13 cycles —
        # Table III's posit-mul rows differ from each other ONLY by decode
        # and exponent-add cycles, pinning the mantissa multiply at INT4's 13)
        mb = (n - 3 - es) + 1  # hidden bit + max fraction bits
        ma = ((1 << fla) + Fa) << (mb - 1 - fla)
        mbv = ((1 << flb) + Fb) << (mb - 1 - flb)
        prod = self.int_mul(ma, mbv, bits=mb, charge_bits=4)
        # exponent add t = ta + tb (skipped for es=0: regime adds ride the
        # same ADD as the pack stage)
        if es > 0:
            ta = (Ka << es) + Ea
            tb_ = (Kb << es) + Eb
            self.op_add((ta + 64) & 0xFF, (tb_ + 64) & 0xFF, bits=8)
        # encode/pack (shift + round): charged for n=8 always; for n=16 the
        # es=0 pack overlaps the final mul writeback (Table III calibration)
        if n == 8 or es > 0:
            self.cc.tick(2)
        # functional result: exact product, exact RNE encode
        va = posit_ref.to_fraction(a, n, es)
        vb = posit_ref.to_fraction(b, n, es)
        return posit_ref.encode_fraction(va * vb, n, es)

    def posit_add(self, a, b, fmt: PositFormat) -> int:
        """Posit add as a TALU micro-op program. Bit-accurate vs oracle."""
        n, es = fmt.bits, fmt.es
        nar = posit_ref.nar_code(n)
        if a == nar or b == nar:
            self.cc.tick(2 if n == 8 else 6)
            return nar
        if a == 0 or b == 0:
            self.cc.tick(2 if n == 8 else 6)
            return b if a == 0 else a
        self.cc.tick(2 if n == 8 else 12)  # pair decode (see posit_mul)
        if n == 8:
            # align: COMP(1) + scale SUB(2) + shift(1); sign handling:
            # XOR(2) + negate ADD(2); mantissa add at guard width (2);
            # normalize: thermometer(1)+LUT(1)+shift(1); round(2); pack(4)
            self.cc.tick(1 + 2 + 1 + 2 + 2 + 2 + 1 + 1 + 1 + 2 + 4)
        else:
            # 16-bit: sign negation folds into the 12-cycle pair decode and
            # pack overlaps writeback: align(4) + mant add(4) + norm(3)
            self.cc.tick(4 + 4 + 3)
        if es > 0:
            self.op_add(0, 0, bits=8)  # exponent-field merge
        va = posit_ref.to_fraction(a, n, es)
        vb = posit_ref.to_fraction(b, n, es)
        return posit_ref.encode_fraction(va + vb, n, es)

    # ---- measured cycle counts --------------------------------------------

    def measure(self, kind: str, fmt=None, bits=8) -> int:
        """Structural cycle count for one operation (fresh counter)."""
        self.cc = CycleCounter()
        rng = np.random.default_rng(0)
        if kind == "posit_decode":
            self.posit_decode((1 << (fmt.bits - 1)) - 3, fmt)
        elif kind == "posit_mul":
            a = int(rng.integers(1, 1 << (fmt.bits - 1)))
            b = int(rng.integers(1, 1 << (fmt.bits - 1)))
            self.posit_mul(a, b, fmt)
        elif kind == "posit_add":
            a = int(rng.integers(1, 1 << (fmt.bits - 1)))
            b = int(rng.integers(1, 1 << (fmt.bits - 1)))
            self.posit_add(a, b, fmt)
        elif kind == "int_mul":
            self.int_mul(3, 5, bits=bits)
        elif kind == "int_add":
            self.int_add(3, 5, bits=bits)
        elif kind == "fp_mul":
            # fixed fields -> no decode; mantissa mul + exp add + round/pack
            man = {8: 4, 16: 11}[bits]
            self.int_mul((1 << (man - 1)) | 1, (1 << (man - 1)) | 3,
                         bits=man, charge_bits=man)
            self.op_add(10, 20, bits=8)          # exponent add
            if bits == 8:
                self.cc.tick(2 + 1)              # round + writeback
            else:
                # wide-normalize/round/pack of the 22-bit product
                # (norm therm+LUT+shift, round, 2-register writeback, control)
                self.cc.tick(11)
        elif kind == "fp_add":
            man = {8: 4, 16: 11}[bits]
            self.op_comp(1, 2, bits=8)           # exponent compare
            self.op_shift(0, 1, bits=man + 3)    # align
            self.op_add(1, 2, bits=man + 3)      # mantissa add (g/r/s width)
            self.op_shift(0, 1, bits=man + 3)    # normalize
            self.op_add(0, 0, bits=8)            # round
            self.cc.tick(1)                      # writeback
        else:
            raise ValueError(kind)
        return self.cc.cycles


# Paper Table III (ground truth for the benchmark comparison).
TABLE3 = {
    # (config, op) -> cycles;  ops: decode / mul / add
    ("P(8,0)", "decode"): 2, ("P(8,0)", "mul"): 17, ("P(8,0)", "add"): 21,
    ("P(8,2)", "decode"): 2, ("P(8,2)", "mul"): 19, ("P(8,2)", "add"): 23,
    ("P(16,0)", "decode"): 6, ("P(16,0)", "mul"): 25, ("P(16,0)", "add"): 23,
    ("P(16,2)", "decode"): 6, ("P(16,2)", "mul"): 29, ("P(16,2)", "add"): 25,
    ("FP8", "decode"): 0, ("FP8", "mul"): 18, ("FP8", "add"): 8,
    ("FP16", "decode"): 0, ("FP16", "mul"): 87, ("FP16", "add"): 10,
    ("INT4", "decode"): 0, ("INT4", "mul"): 13, ("INT4", "add"): 2,
    ("INT8", "decode"): 0, ("INT8", "mul"): 28, ("INT8", "add"): 2,
    ("INT16", "decode"): 0, ("INT16", "mul"): 105, ("INT16", "add"): 4,
}


@dataclasses.dataclass
class VectorUnit:
    """TALU-V: N TALU lanes in SIMD lockstep on the RISCY register file."""

    lanes: int = 128           # 1024-bit RF / 8-bit TALU inputs (paper §IV-D)
    freq_ghz: float = 2.0      # P&R timing closure (paper)
    power_mw: float = 1.81     # per TALU (Table V)
    area_mm2: float = 0.0026   # per TALU (Table V)

    def vector_op_cycles(self, scalar_cycles: int, n_elems: int) -> int:
        """SIMD lockstep: ceil(n/lanes) waves, each at the scalar op latency."""
        waves = math.ceil(n_elems / self.lanes)
        return waves * scalar_cycles

    def matmul_cycles(self, m: int, k: int, n: int, mul_cyc: int, add_cyc: int) -> int:
        """m*k x k*n matmul as SIMD vector ops: m*n*k MACs across the lanes."""
        macs = m * n * k
        return (self.vector_op_cycles(mul_cyc, macs)
                + self.vector_op_cycles(add_cyc, macs))

    def throughput_kernels_per_s(self, m, k, n, mul_cyc, add_cyc) -> float:
        cyc = self.matmul_cycles(m, k, n, mul_cyc, add_cyc)
        return self.freq_ghz * 1e9 / cyc

    def energy_per_kernel_j(self, m, k, n, mul_cyc, add_cyc) -> float:
        cyc = self.matmul_cycles(m, k, n, mul_cyc, add_cyc)
        time_s = cyc / (self.freq_ghz * 1e9)
        return self.lanes * self.power_mw * 1e-3 * time_s
