"""Transprecision-computing (TC) policy engine.

The paper's TALU reconfigures at runtime between Posit/FP/INT and bitwidths,
"at the node level or at the layer level according to the application
requirements" (§I).  On the TPU framework this becomes a *policy object*:

* role-level defaults  — what format each tensor role uses
  (attention weights, MLP weights, embeddings, KV cache, gradient wire
  format, activations),
* layer-level overrides — per-layer-index format maps (layer granularity),
* node-level overrides  — per-named-op maps (node granularity).

Policies are static, hashable metadata: switching policy between steps picks
a different jit specialization, which is the software analogue of flipping
``posit_en``/bitwidth control lines — no overprovisioned datapath, no
recompilation of unrelated variants.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from . import quant
from .formats import POSIT4_1, POSIT8_2, POSIT16_2, PositFormat, get

ROLES = (
    "attn_weights", "mlp_weights", "embed_weights", "activations",
    "kv_cache", "grad_wire", "ssm_state",
)


@dataclasses.dataclass(frozen=True)
class TCPolicy:
    """Transprecision policy. ``None`` for a role means full precision."""

    name: str = "bf16"
    attn_weights: Optional[str] = None
    mlp_weights: Optional[str] = None
    embed_weights: Optional[str] = None
    activations: Optional[str] = None
    kv_cache: Optional[str] = None
    grad_wire: Optional[str] = None
    ssm_state: Optional[str] = None
    # layer granularity: ((layer_idx, role, fmt), ...) — hashable
    layer_overrides: Tuple[Tuple[int, str, str], ...] = ()
    # node granularity: ((op_name, fmt), ...)
    node_overrides: Tuple[Tuple[str, str], ...] = ()
    # serving: store the KV cache as packed posit codes (decode-on-read)
    packed_kv: bool = False
    # serving KV-cache storage format: one of KV_FORMATS
    # (f32 | bf16 | posit16 | posit8 | posit4) or None.  None defers to the
    # legacy (packed_kv, kv_cache) pair, else full precision at model dtype.
    kv_format: Optional[str] = None
    # serving KV-cache layout: "ring" reserves a dense max_len ring per
    # slot; "paged" uses a shared page pool + per-sequence page tables
    # (vLLM-style), so HBM tracks live tokens instead of the worst case.
    kv_layout: str = "ring"
    # tokens per page for the paged layout (static: picks the Pallas
    # page-walk block shape, so it is a jit specialization key like the
    # formats themselves)
    kv_page_size: int = 16

    def fmt_for(self, role: str, layer: Optional[int] = None,
                node: Optional[str] = None) -> Optional[str]:
        if node is not None:
            for op_name, f in self.node_overrides:
                if op_name == node:
                    return f
        if layer is not None:
            for li, r, f in self.layer_overrides:
                if li == layer and r == role:
                    return f
        return getattr(self, role)

    def quantize_weight(self, w, role: str, layer=None, node=None):
        """Weight hook on every matmul.  Two modes:

        * packed serving — ``w`` is already a QuantizedTensor (posit codes
          in HBM): decode-on-load, the paper's TALU datapath.  HBM traffic
          for the weight is ``bits/16`` of the bf16 baseline.
        * QAT training — fake-quant with STE so gradients flow.
        """
        if isinstance(w, quant.QuantizedTensor):
            return w.dequantize(jnp.bfloat16)
        f = self.fmt_for(role, layer, node)
        if f is None:
            return w
        # per-output-channel scaling on the last axis
        return quant.fake_quant(w, f, axis=tuple(range(w.ndim - 1)))

    def storage_quantize(self, w, role: str, layer=None):
        """Real packed storage (serving / memory-bound path)."""
        f = self.fmt_for(role, layer)
        if f is None:
            return w
        return quant.quantize(w, get(f), axis=tuple(range(w.ndim - 1)))

    def bits_for(self, role: str) -> int:
        f = getattr(self, role)
        return get(f).bits if f else 16


# ---------------------------------------------------------------------------
# KV-cache storage resolution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVStorage:
    """Resolved serving KV-cache storage: a float dtype OR packed posit.

    ``fmt`` set -> the cache ring holds posit codes + a per-row (token x
    head) f32 power-of-two scale; ``packed`` nibble-packs sub-byte codes
    two-per-byte.  ``fmt`` None -> plain float storage in ``dtype``.
    """

    name: str
    fmt: Optional[PositFormat] = None
    dtype_name: Optional[str] = None
    packed: bool = False

    @property
    def is_posit(self) -> bool:
        return self.fmt is not None

    @property
    def dtype(self):
        return {"f32": jnp.float32, "bf16": jnp.bfloat16}[self.dtype_name]

    def bytes_per_value(self, head_dim: int) -> float:
        """HBM bytes per cached K/V element, scale overhead amortized."""
        if self.fmt is None:
            return {"f32": 4.0, "bf16": 2.0}[self.dtype_name]
        itemsize = jnp.dtype(self.fmt.storage_dtype).itemsize
        code = itemsize / 2.0 if self.packed else float(itemsize)
        return code + 4.0 / head_dim


KV_FORMATS = {
    "f32": KVStorage("f32", dtype_name="f32"),
    "bf16": KVStorage("bf16", dtype_name="bf16"),
    "posit16": KVStorage("posit16", fmt=POSIT16_2),
    "posit8": KVStorage("posit8", fmt=POSIT8_2),
    "posit4": KVStorage("posit4", fmt=POSIT4_1, packed=True),
}


def kv_storage(policy: Optional["TCPolicy"]) -> Optional[KVStorage]:
    """Resolve a policy's KV-cache storage; None means model-dtype floats.

    Precedence: explicit ``kv_format`` > legacy ``packed_kv`` + posit
    ``kv_cache`` role > None.
    """
    if policy is None:
        return None
    if policy.kv_format is not None:
        if policy.kv_format not in KV_FORMATS:
            raise KeyError(f"unknown kv_format {policy.kv_format!r}; "
                           f"known: {sorted(KV_FORMATS)}")
        return KV_FORMATS[policy.kv_format]
    if policy.packed_kv and policy.kv_cache:
        f = get(policy.kv_cache)
        if isinstance(f, PositFormat):
            return KVStorage(f.name, fmt=f, packed=f.bits < 8)
    return None


def draft_policy(policy: "TCPolicy", weights_fmt: str = "posit8_2",
                 kv_format: str = "posit8") -> "TCPolicy":
    """Derive the low-precision *draft* policy for self-speculative decode.

    The draft pass runs the SAME weights through the TALU's cheap mode:
    posit8 weight compute and a posit8 KV ring by default — the software
    analogue of dropping the ALU bitwidth for a throwaway pass and
    re-raising it for the verify.  The draft cache is always a ring (it is
    private, rolled back wholesale, and never shared), and layer/node
    overrides are dropped: the draft is uniformly cheap by construction.
    """
    base = get_policy(policy)
    return dataclasses.replace(
        base,
        name=f"{base.name}+draft_{kv_format}",
        attn_weights=weights_fmt,
        mlp_weights=weights_fmt,
        embed_weights=base.embed_weights or "posit16_2",
        kv_format=kv_format,
        kv_layout="ring",
        packed_kv=False,
        layer_overrides=(),
        node_overrides=(),
    )


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# Full precision (baseline; bf16 compute, fp32 master/optimizer)
BF16 = TCPolicy(name="bf16")

# The paper's edge configuration: "Posit P(8,2) is exclusively used for
# vector operations, as this configuration is most used for DNNs deployed on
# edge devices" (§IV-D).
PAPER_EDGE = TCPolicy(
    name="paper_edge_p8",
    attn_weights="posit8_2",
    mlp_weights="posit8_2",
    embed_weights="posit16_2",
    kv_cache="posit8_2",
)

# Mixed transprecision: wider formats where sensitivity is high.
MIXED_TC = TCPolicy(
    name="mixed_tc",
    attn_weights="posit8_2",
    mlp_weights="posit8_2",
    embed_weights="posit16_2",
    kv_cache="posit16_2",
    grad_wire="posit16_2",
)

# INT8 weight-only (the TALU INT mode; standard edge baseline)
INT8_W = TCPolicy(name="int8_w", attn_weights="int8", mlp_weights="int8",
                  embed_weights="int8")

# FP8 weight-only (the TALU FP mode)
FP8_W = TCPolicy(name="fp8_w", attn_weights="fp8_e4m3", mlp_weights="fp8_e4m3",
                 embed_weights="fp8_e4m3")

# Packed posit serving: weights AND KV cache live in HBM as posit8 codes,
# decoded on load (the paper's decode-on-read datapath at datacenter scale)
SERVE_P8 = TCPolicy(name="serve_posit8",
                    attn_weights="posit8_2", mlp_weights="posit8_2",
                    kv_cache="posit8_2", packed_kv=True)
SERVE_P16 = TCPolicy(name="serve_posit16",
                     attn_weights="posit16_2", mlp_weights="posit16_2",
                     kv_cache="posit16_2", packed_kv=True)

PRESETS = {p.name: p for p in [BF16, PAPER_EDGE, MIXED_TC, INT8_W, FP8_W,
                               SERVE_P8, SERVE_P16]}


# ---------------------------------------------------------------------------
# Packed-parameter conversion (serving)
# ---------------------------------------------------------------------------

_ROLE_BY_NAME = {
    "wq": "attn_weights", "wk": "attn_weights", "wv": "attn_weights",
    "wo": "attn_weights", "wq_x": "attn_weights", "wk_x": "attn_weights",
    "wv_x": "attn_weights", "wo_x": "attn_weights",
    "wi": "mlp_weights", "wo_mlp": "mlp_weights",
    "wx": "mlp_weights", "wy": "mlp_weights", "w_out": "mlp_weights",
    "w_a": "mlp_weights", "w_x": "mlp_weights",
    "in_proj": "mlp_weights", "out_proj": "mlp_weights",
}


def pack_params(params, policy: TCPolicy, abstract: bool = False):
    """Convert matrix weight leaves to packed posit QuantizedTensors per
    the policy's role formats (embeddings/norms/vectors stay unpacked —
    the embedding gather wants code-row indexing, left as future work).

    ``abstract=True`` builds the ShapeDtypeStruct skeleton for the dry-run.
    """
    from .formats import PositFormat

    def pack(kp, w):
        name = None
        for k in reversed(kp):
            key = str(getattr(k, "key", getattr(k, "idx", k)))
            if not key.isdigit():
                name = key
                break
        role = _ROLE_BY_NAME.get(name)
        if role is None or w.ndim < 2:
            return w
        f = policy.fmt_for(role)
        if f is None or not isinstance(get(f), PositFormat):
            return w
        fmt = get(f)
        # stacked per-period block leaves keep their leading stack axis in
        # the scale so lax.scan can slice params and scales together.
        # Channel choice follows the sharding rules (launch/mesh.py): the
        # per-channel scale must live on a dim whose sharding matches the
        # code tensor's spec under prefix broadcast — last dim for
        # input-major weights, second-to-last for output projections.
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        stacked = keys[0] == "blocks" and w.ndim >= 3
        out_in = (name in ("wo", "wo_mlp", "w_out", "out_proj", "wo_x")
                  and "moe" not in keys)
        ch = w.ndim - 2 if out_in else w.ndim - 1
        keep = {ch} | ({0} if stacked else set())
        axis = tuple(i for i in range(w.ndim) if i not in keep)
        if abstract:
            import jax
            scale_shape = tuple(w.shape[i] if i in keep else 1
                                for i in range(w.ndim))
            return quant.QuantizedTensor(
                jax.ShapeDtypeStruct(w.shape, fmt.storage_dtype),
                jax.ShapeDtypeStruct(scale_shape, jnp.float32), fmt)
        return quant.quantize(w, fmt, axis=axis)

    import jax
    return jax.tree_util.tree_map_with_path(pack, params)


def get_policy(name) -> TCPolicy:
    if isinstance(name, TCPolicy):
        return name
    if name not in PRESETS:
        raise KeyError(f"unknown TC policy {name!r}; known: {sorted(PRESETS)}")
    return PRESETS[name]


def hbm_bytes_per_param(policy: TCPolicy, role: str = "mlp_weights") -> float:
    f = getattr(policy, role)
    return (get(f).bits / 8.0) if f else 2.0  # bf16 default
