"""Exact posit oracle — pure Python integers / fractions.

softposit (which the paper validates against) is not installable offline, so
this module re-implements its semantics exactly and serves as the ground
truth for every vectorized / Pallas implementation in the framework:

  * two's-complement handling of negative posits,
  * regime/exponent/fraction field extraction with right-zero-filled
    truncated exponents,
  * bit-level round-to-nearest-even (guard/sticky on the assembled code),
  * saturation to maxpos/minpos (posit results never round to 0 or NaR).

Everything here is exact: decode produces `fractions.Fraction`; encode
consumes a Fraction (or float, converted exactly) and performs integer-only
RNE assembly.  NaR is represented as Python ``None`` at the value level.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "decode_fields", "to_fraction", "to_float", "encode", "encode_fraction",
    "add", "mul", "sub", "fma", "all_values", "minpos", "maxpos", "nar_code",
]


def nar_code(n: int) -> int:
    return 1 << (n - 1)


def _mask(b: int) -> int:
    return (1 << b) - 1


def decode_fields(code: int, n: int, es: int) -> Tuple[int, int, int, int, int]:
    """Return (sign, K, E, f_len, F) for a non-zero, non-NaR code.

    Fields are extracted from |code| (two's complement magnitude), per the
    posit standard.  Truncated exponent bits are zero-filled on the right.
    """
    code &= _mask(n)
    s = code >> (n - 1)
    mag = code if s == 0 else ((-code) & _mask(n))
    body = mag & _mask(n - 1)
    assert body != 0, "zero/NaR have no fields"
    lead = (body >> (n - 2)) & 1
    # run length of leading bits equal to `lead`
    r = 0
    for i in range(n - 2, -1, -1):
        if (body >> i) & 1 == lead:
            r += 1
        else:
            break
    K = (r - 1) if lead == 1 else -r
    rem = (n - 1) - r - 1  # bits after the stop bit; -1 if regime fills body
    rem = max(rem, 0)
    rest = body & _mask(rem)
    e_have = min(es, rem)
    E = (rest >> (rem - e_have)) << (es - e_have)  # right zero-fill
    f_len = max(rem - es, 0)
    F = rest & _mask(f_len)
    return s, K, E, f_len, F


def to_fraction(code: int, n: int, es: int) -> Optional[Fraction]:
    """Exact value of a posit code; 0 -> Fraction(0); NaR -> None."""
    code &= _mask(n)
    if code == 0:
        return Fraction(0)
    if code == nar_code(n):
        return None
    s, K, E, f_len, F = decode_fields(code, n, es)
    t = (K << es) + E
    mant = Fraction((1 << f_len) + F, 1 << f_len)
    val = mant * (Fraction(2) ** t)
    return -val if s else val


def to_float(code: int, n: int, es: int) -> float:
    f = to_fraction(code, n, es)
    if f is None:
        return float("nan")
    return float(f)  # exact for n<=32 (<=27 frac bits, |t|<=120)


def minpos(n: int, es: int) -> Fraction:
    return Fraction(2) ** (-(1 << es) * (n - 2))


def maxpos(n: int, es: int) -> Fraction:
    return Fraction(2) ** ((1 << es) * (n - 2))


def encode_fraction(x: Optional[Fraction], n: int, es: int) -> int:
    """Exact bit-RNE encoding of a Fraction; None -> NaR. Saturating."""
    if x is None:
        return nar_code(n)
    if x == 0:
        return 0
    s = 1 if x < 0 else 0
    a = -x if s else x
    # t = floor(log2(a)) exactly
    num, den = a.numerator, a.denominator
    t = num.bit_length() - den.bit_length()
    if (num >> t if t >= 0 else num << -t) < den:  # 2^t > a ?
        t -= 1
    # a = 2^t * (1 + frac), frac in [0, 1)
    frac = a / (Fraction(2) ** t) - 1
    assert 0 <= frac < 1
    K = t >> es
    E = t - (K << es)
    # regime saturation: K = n-2 already fills the body with ones (the stop
    # bit is cut), so every value with K >= n-2 is >= maxpos.
    if K >= n - 2:
        body = _mask(n - 1)  # maxpos
    elif K <= -(n - 1):
        body = 1  # minpos
    else:
        if K >= 0:
            reg, w0 = ((_mask(K + 1)) << 1), K + 2  # K+1 ones then stop 0
        else:
            reg, w0 = 1, -K + 1  # -K zeros then stop 1
        avail = (n - 1) - w0  # bits available for exponent+fraction
        # exponent+fraction as an exact binary expansion with avail+1 bits
        # kept (last bit = guard) and a sticky for the rest.
        if avail + 1 - es >= 0:
            ef_shift = avail + 1 - es  # fraction bits incl. guard
            scaled = frac * (1 << ef_shift)
            fbits = int(scaled)  # floor
            sticky = 1 if (scaled - fbits) != 0 else 0
            efg = (E << ef_shift) | fbits  # es + avail+1 - es = avail+1 bits
        else:
            # even the exponent is cut: keep avail+1 top bits of E
            cut = es - (avail + 1)
            efg = E >> cut
            sticky = 1 if ((E & _mask(cut)) != 0 or frac != 0) else 0
        guard = efg & 1
        kept = efg >> 1
        body = (reg << avail) | kept
        if guard and (sticky or (body & 1)):
            body += 1
        # never round to 0 / NaR; saturate
        body = max(1, min(body, _mask(n - 1)))
    code = body if s == 0 else ((-body) & _mask(n))
    return code


def encode(x, n: int, es: int) -> int:
    """Encode a Python/numpy float with exact semantics (float -> Fraction)."""
    if isinstance(x, Fraction):
        return encode_fraction(x, n, es)
    xf = float(x)
    if np.isnan(xf) or np.isinf(xf):
        return nar_code(n)
    return encode_fraction(Fraction(xf), n, es)


def _binop(a: int, b: int, n: int, es: int, op) -> int:
    if a == nar_code(n) or b == nar_code(n):
        return nar_code(n)
    va, vb = to_fraction(a, n, es), to_fraction(b, n, es)
    return encode_fraction(op(va, vb), n, es)


def add(a: int, b: int, n: int, es: int) -> int:
    return _binop(a, b, n, es, lambda x, y: x + y)


def sub(a: int, b: int, n: int, es: int) -> int:
    return _binop(a, b, n, es, lambda x, y: x - y)


def mul(a: int, b: int, n: int, es: int) -> int:
    return _binop(a, b, n, es, lambda x, y: x * y)


def fma(a: int, b: int, c: int, n: int, es: int) -> int:
    """Fused multiply-add: round(a*b + c) with a single rounding (quire-like)."""
    if nar_code(n) in (a, b, c):
        return nar_code(n)
    va, vb, vc = (to_fraction(x, n, es) for x in (a, b, c))
    return encode_fraction(va * vb + vc, n, es)


def all_values(n: int, es: int) -> np.ndarray:
    """float64 value of every code 0..2^n-1 (NaR -> nan). Exact for n<=32."""
    out = np.empty(1 << n, dtype=np.float64)
    for c in range(1 << n):
        out[c] = to_float(c, n, es)
    return out
