"""Quantize/dequantize across the TALU format family + QuantizedTensor.

This is the bridge between the paper's transprecision formats and JAX models:

* ``QuantizedTensor`` — a pytree carrying packed codes + an optional runtime
  scale + the (static) format descriptor.  Posit tensors may carry a
  power-of-two scale ("exponent bias", DESIGN.md §7.4) so tapered precision
  is centred on the tensor's magnitude; int tensors carry an affine scale.
* ``quantize`` / ``dequantize`` — storage-format conversion (the TPU
  adaptation of TALU's decode-on-read / encode-on-write datapath).
* ``fake_quant`` — straight-through-estimator quantization for QAT-style
  transprecision training.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import posit
from .formats import FloatFormat, Format, IntFormat, PositFormat, get


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Packed low-precision tensor: ``value ~= decode(data) * scale``."""

    data: jax.Array
    scale: Optional[jax.Array]  # None, scalar, or broadcastable per-channel
    fmt: Format                 # static

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def tree_flatten(self):
        if self.scale is None:
            return (self.data,), (self.fmt, False)
        return (self.data, self.scale), (self.fmt, True)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, has_scale = aux
        if has_scale:
            return cls(children[0], children[1], fmt)
        return cls(children[0], None, fmt)

    def dequantize(self, dtype=jnp.float32):
        return dequantize(self, dtype)

    @property
    def nbytes_packed(self) -> int:
        n = int(np.prod(self.shape)) * self.fmt.bits / 8
        if self.scale is not None:
            n += int(np.prod(jnp.shape(self.scale))) * 4
        return int(n)


def _pow2_scale(x, axis):
    """Power-of-two scale centring |x| median-ish (abs-mean) near 1.0."""
    absx = jnp.abs(x)
    mean = jnp.mean(absx, axis=axis, keepdims=axis is not None, where=absx > 0)
    mean = jnp.maximum(mean, 1e-30)
    return jnp.exp2(jnp.round(jnp.log2(mean)))


def quantize(x, fmt, axis=None, scaled: bool = True) -> QuantizedTensor:
    """Quantize a float tensor into packed storage codes.

    posit: optional power-of-two runtime scale (exact to apply/remove).
    int:   symmetric per-tensor (axis=None) or per-channel absmax scale.
    float: native dtype cast (bf16/fp16/fp8 via XLA RNE).
    """
    fmt = get(fmt)
    x = jnp.asarray(x, jnp.float32)
    if isinstance(fmt, PositFormat):
        if scaled:
            s = _pow2_scale(x, axis)
            codes = posit.encode_f32(x / s, fmt)
            return QuantizedTensor(codes, s.astype(jnp.float32), fmt)
        return QuantizedTensor(posit.encode_f32(x, fmt), None, fmt)
    if isinstance(fmt, IntFormat):
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
        s = jnp.maximum(amax, 1e-30) / fmt.qmax
        q = jnp.clip(jnp.round(x / s), fmt.qmin, fmt.qmax)
        return QuantizedTensor(q.astype(fmt.storage_dtype), s.astype(jnp.float32), fmt)
    if isinstance(fmt, FloatFormat):
        return QuantizedTensor(x.astype(fmt.jnp_dtype), None, fmt)
    raise TypeError(fmt)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32):
    fmt = qt.fmt
    if isinstance(fmt, PositFormat):
        v = posit.decode_to_f32(qt.data, fmt)
        v = jnp.nan_to_num(v)  # NaR -> 0 on the ML path
    elif isinstance(fmt, IntFormat):
        v = qt.data.astype(jnp.float32)
    else:
        v = qt.data.astype(jnp.float32)
    if qt.scale is not None:
        v = v * qt.scale
    return v.astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant(x, fmt_name: str, axis=None):
    """Straight-through quantization: forward rounds through ``fmt``,
    backward passes gradients unchanged (STE)."""
    qt = quantize(x, get(fmt_name), axis=axis)
    return dequantize(qt, jnp.result_type(x))


def _fq_fwd(x, fmt_name, axis):
    return fake_quant(x, fmt_name, axis), None


def _fq_bwd(fmt_name, axis, res, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def maybe_dequant(w, dtype=jnp.bfloat16):
    """Pass-through for plain arrays; decode for packed QuantizedTensors.
    Used at weight-consumption sites that bypass the TC policy hook."""
    if isinstance(w, QuantizedTensor):
        return dequantize(w, dtype)
    return w


def quantization_mse(x, fmt, axis=None) -> jax.Array:
    """Mean squared quantization error of storing ``x`` in ``fmt``."""
    qt = quantize(x, get(fmt), axis=axis)
    return jnp.mean((dequantize(qt) - jnp.asarray(x, jnp.float32)) ** 2)
