"""Q-function threshold-logic primitive (paper Eq. 3) and Table I/II mappings.

A threshold function is a unate Boolean function with linearly separable
on/off sets (Eq. 2).  The paper's generalized template is

    Q(p, Z0, X, Z1, Y) = [ Z0 + sum_j 2^j X_j  >=  Z1 + sum_j 2^j Y_j ]

Eight physical Q blocks form a *cluster*; TALU has two clusters (PC, SC).
Every TALU operation in Tables I and II is an argument mapping of this single
template.  This module implements the template bit-accurately (vectorized
numpy — this layer is the cycle-level simulator substrate, not the TPU hot
path) and exposes each table row as a function of packed integer operands.

Conventions: operands are unsigned integers held in numpy arrays; ``p`` is
the slice width (the paper uses p = 8).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "q_eval", "q_and", "q_or", "q_not", "q_comp", "q_add_carry", "q_add_sum",
    "q_xor_step1", "q_xor_step2", "q_posit_decode_compare", "cluster_add",
    "cluster_and", "cluster_or", "cluster_not", "cluster_xor",
]


def _bit(v, i):
    return (np.asarray(v, np.int64) >> i) & 1


def q_eval(z0, x, z1, y):
    """The Q template on already-summed integer arguments.

    x, y are the integer values sum_j 2^j X_j / sum_j 2^j Y_j (callers build
    them from bit selections exactly as Tables I/II specify).
    """
    return ((np.asarray(z0, np.int64) + np.asarray(x, np.int64)) >=
            (np.asarray(z1, np.int64) + np.asarray(y, np.int64))).astype(np.int64)


# --- Table I: Primary Cluster ops (one Q evaluation per output bit) --------

def q_and(a, b, i):
    return q_eval(0, _bit(a, i), 1, 1 - _bit(b, i))          # {0^{p-1}, ~B_i}


def q_or(a, b, i):
    return q_eval(0, _bit(a, i), 0, 1 - _bit(b, i))


def q_not(b, i):
    return q_eval(0, 1 - _bit(b, i), 1, 0)


def q_comp(a, b, i, p=8):
    """A[i:0] >= B[i:0]."""
    m = (1 << (i + 1)) - 1
    return q_eval(0, np.asarray(a, np.int64) & m, 0, np.asarray(b, np.int64) & m)


def q_add_carry(a, b, i, c0=0):
    """ADD step 1: Carry_{i+1} = [C0 + A[i:0] >= 1 + ~B[i:0]] (Table I)."""
    m = (1 << (i + 1)) - 1
    nb = (~np.asarray(b, np.int64)) & m
    return q_eval(c0, np.asarray(a, np.int64) & m, 1, nb)


def q_xor_step1(a, b, i):
    return q_and(a, b, i)


def q_posit_decode_compare(t_val, i, p=8):
    """Posit decode row: V_i = [T[p-2:0] >= 2^{p-1}-1-(2^i-1)]."""
    thr = (1 << (p - 1)) - 1 - ((1 << i) - 1)
    return q_eval(0, np.asarray(t_val, np.int64), 0, thr)


# --- Table II: Secondary Cluster ops ---------------------------------------

def q_add_sum(a, b, i, carry_i, carry_ip1):
    """ADD step 2: Sum_i = [A_i + B_i >= 2*Carry_{i+1} + ~Carry_i]."""
    y = 2 * np.asarray(carry_ip1, np.int64) + (1 - np.asarray(carry_i, np.int64))
    return q_eval(_bit(a, i), _bit(b, i), 0, y)


def q_xor_step2(a, b, i, and_i):
    """XOR step 2: [A_i + B_i >= 1 + 2*AND_i]."""
    return q_eval(_bit(a, i), _bit(b, i), 1, 2 * np.asarray(and_i, np.int64))


# --- whole-cluster (p-bit) operations: p parallel Q blocks, 1 cycle each ---

def cluster_and(a, b, p=8):
    return sum(q_and(a, b, i) << i for i in range(p))


def cluster_or(a, b, p=8):
    return sum(q_or(a, b, i) << i for i in range(p))


def cluster_not(b, p=8):
    return sum(q_not(b, i) << i for i in range(p))


def cluster_add(a, b, p=8, c0=0):
    """Two-cycle ADD: carry plane on PC, sum plane on SC.

    Returns (sum mod 2^p, carry_out).  This is the paper's key demonstration
    that both the CLA carries and the sum bits are threshold functions.
    """
    carries = [np.asarray(c0, np.int64)]
    for i in range(p):
        carries.append(q_add_carry(a, b, i, c0))
    s = sum(q_add_sum(a, b, i, carries[i], carries[i + 1]) << i for i in range(p))
    return s, carries[p]


def cluster_xor(a, b, p=8):
    """Two-cycle XOR: AND plane (PC) then XOR plane (SC)."""
    ands = [q_xor_step1(a, b, i) for i in range(p)]
    return sum(q_xor_step2(a, b, i, ands[i]) << i for i in range(p))
