"""Number-format algebra for transprecision computing.

The paper's TALU supports Posit / FP / INT at multiple bitwidths with runtime
reconfiguration.  This module is the single source of truth for format
descriptors used across the framework: the quantizer, the TC policy engine,
the Pallas kernels, and the TALU cycle simulator all key off these objects.

Formats are immutable, hashable dataclasses so they can live inside jit-cache
keys and TC policies (pytrees of static metadata).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Format:
    """Base class for all number formats."""

    name: str
    bits: int

    @property
    def bytes(self) -> float:
        return self.bits / 8.0


@dataclasses.dataclass(frozen=True)
class PositFormat(Format):
    """Posit P(n, es) per Gustafson 2017 / posit standard conventions.

    ``bias`` is a power-of-two scale applied to the *total* exponent when a
    tensor's values cluster away from 1.0 (beyond-paper extension, see
    DESIGN.md §7.4).  bias=0 is the paper-faithful format.
    """

    es: int = 2
    bias: int = 0

    def __post_init__(self):
        if not (2 <= self.bits <= 32):
            raise ValueError(f"posit bits must be in [2,32], got {self.bits}")
        if not (0 <= self.es <= 3):
            raise ValueError(f"posit es must be in [0,3], got {self.es}")

    @property
    def useed(self) -> int:
        return 1 << (1 << self.es)

    @property
    def max_scale(self) -> int:
        """Max total binary exponent t (maxpos = 2**max_scale)."""
        return (1 << self.es) * (self.bits - 2)

    @property
    def storage_dtype(self):
        return {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32}[
            8 * max(1, (self.bits + 7) // 8)
        ]

    @property
    def np_storage_dtype(self):
        return {8: np.uint8, 16: np.uint16, 32: np.uint32}[
            8 * max(1, (self.bits + 7) // 8)
        ]


@dataclasses.dataclass(frozen=True)
class IntFormat(Format):
    """Signed integer with an implicit per-tensor/per-channel scale."""

    symmetric: bool = True

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1)) + (1 if self.symmetric else 0)

    @property
    def storage_dtype(self):
        return jnp.int8 if self.bits <= 8 else (jnp.int16 if self.bits <= 16 else jnp.int32)


@dataclasses.dataclass(frozen=True)
class FloatFormat(Format):
    """IEEE-style float; maps to a native jnp dtype where one exists."""

    exp_bits: int = 8
    man_bits: int = 23

    @property
    def jnp_dtype(self):
        key = (self.bits, self.exp_bits, self.man_bits)
        table = {
            (32, 8, 23): jnp.float32,
            (16, 5, 10): jnp.float16,
            (16, 8, 7): jnp.bfloat16,
            (8, 4, 3): jnp.float8_e4m3fn,
            (8, 5, 2): jnp.float8_e5m2,
        }
        if key not in table:
            raise ValueError(f"no native dtype for {self}")
        return table[key]


# ---------------------------------------------------------------------------
# Registry (the formats TALU supports, plus native TPU compute formats).
# ---------------------------------------------------------------------------

POSIT4_1 = PositFormat("posit4_1", 4, es=1)  # sub-byte KV-cache storage
POSIT8_0 = PositFormat("posit8_0", 8, es=0)
POSIT8_1 = PositFormat("posit8_1", 8, es=1)
POSIT8_2 = PositFormat("posit8_2", 8, es=2)   # the paper's DNN format
POSIT16_0 = PositFormat("posit16_0", 16, es=0)
POSIT16_1 = PositFormat("posit16_1", 16, es=1)
POSIT16_2 = PositFormat("posit16_2", 16, es=2)
POSIT32_2 = PositFormat("posit32_2", 32, es=2)

INT4 = IntFormat("int4", 4)
INT8 = IntFormat("int8", 8)
INT16 = IntFormat("int16", 16)
INT32 = IntFormat("int32", 32)

FP8_E4M3 = FloatFormat("fp8_e4m3", 8, exp_bits=4, man_bits=3)
FP8_E5M2 = FloatFormat("fp8_e5m2", 8, exp_bits=5, man_bits=2)
FP16 = FloatFormat("fp16", 16, exp_bits=5, man_bits=10)
BF16 = FloatFormat("bf16", 16, exp_bits=8, man_bits=7)
FP32 = FloatFormat("fp32", 32, exp_bits=8, man_bits=23)

REGISTRY = {
    f.name: f
    for f in [
        POSIT4_1, POSIT8_0, POSIT8_1, POSIT8_2, POSIT16_0, POSIT16_1, POSIT16_2,
        POSIT32_2, INT4, INT8, INT16, INT32, FP8_E4M3, FP8_E5M2, FP16,
        BF16, FP32,
    ]
}


def get(name: str) -> Format:
    if isinstance(name, Format):
        return name
    if name not in REGISTRY:
        raise KeyError(f"unknown format {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
