"""Vectorized posit codec + arithmetic in pure JAX.

This is the paper's primary algorithmic contribution mapped to the TPU VPU:

* ``thermometer_decode`` implements Algorithm 1 verbatim: n-1 *parallel
  threshold comparisons* ``V_i = T >= 2^{n-1} - 2^i`` produce a thermometer
  code whose popcount is the regime run-length; a LUT (here: popcount — we
  prove the equivalence in tests) yields the regime value K, and one left
  shift exposes exponent and fraction.  Branch-free and fixed-depth, exactly
  as on the TALU clusters.
* ``decode_to_f32`` / ``encode_f32`` convert between posit codes and float32
  with bit-exact softposit semantics (see ``posit_ref``): two's-complement
  negatives, right-zero-filled truncated exponents, bit-level RNE,
  maxpos/minpos saturation.
* ``add`` / ``mul`` / ``fma`` are *exact* posit arithmetic for n<=16 (int32
  internals) — the software analogue of TALU's compute mode, used by the
  edge-emulation path and the accuracy benchmarks.

All functions are shape-polymorphic and jit/vmap/shard_map-friendly; bit
manipulation uses uint32 (logical shifts) and int32 (signed exponents) only,
so nothing here requires x64 mode.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import PositFormat

U32 = jnp.uint32
I32 = jnp.int32


def _u(x):
    return jnp.asarray(x).astype(U32)


def _i(x):
    return jnp.asarray(x).astype(I32)


def _mask(b):
    """(1<<b)-1 as uint32, valid for b in [0,32], b may be a traced array."""
    b = jnp.asarray(b, U32)
    full = jnp.asarray(0xFFFFFFFF, U32)
    return jnp.where(b >= 32, full, (U32(1) << jnp.minimum(b, U32(31))) - U32(1))


def _shl(x, k):
    """uint32 left shift, clamped: k>=32 -> 0; k is non-negative."""
    k = jnp.asarray(k, U32)
    return jnp.where(k >= 32, U32(0), _u(x) << jnp.minimum(k, U32(31)))


def _shr(x, k):
    """uint32 logical right shift, clamped: k>=32 -> 0."""
    k = jnp.asarray(k, U32)
    return jnp.where(k >= 32, U32(0), _u(x) >> jnp.minimum(k, U32(31)))


def _negate_code(u, n):
    """Two's-complement negation within n bits (uint32)."""
    return (~u + U32(1)) & _mask(n)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def thermometer_decode(codes, fmt: PositFormat):
    """Algorithm 1's Find_R, verbatim: parallel threshold comparisons.

    Returns (V, r, K) where V is the (..., n-1) thermometer matrix of
    Q-function outputs ``V_i = T[n-2:0] >= 2^{n-1}-1-(2^i-1)``, r = popcount(V)
    is the regime run length and K the regime value.  Operates on the raw
    code the way the TALU does (magnitude handling happens upstream).
    """
    n = fmt.bits
    u = _u(jnp.asarray(codes))
    body = u & _mask(n - 1)
    lead = _shr(body, n - 2) & U32(1)
    t_val = jnp.where(lead == 1, body, (~body) & _mask(n - 1))
    i = jnp.arange(n - 1, dtype=np.int64)
    thresholds = ((1 << (n - 1)) - 1 - ((1 << i) - 1)).astype(np.uint32)  # 2^{n-1}-2^i
    v = (t_val[..., None] >= thresholds).astype(U32)
    r = jnp.sum(v, axis=-1, dtype=U32)
    k = jnp.where(lead == 1, _i(r) - 1, -_i(r))
    return v, r, k


def regime_lut(fmt: PositFormat) -> np.ndarray:
    """The paper's LUT: thermometer popcount -> K (for lead=1 plane).

    Built by enumeration, used in tests to prove LUT[V] == popcount-derived K.
    """
    n = fmt.bits
    return np.arange(n, dtype=np.int32) - 1


def _decode_parts(codes, fmt: PositFormat):
    """codes -> (s, t, f_len, F, is_zero, is_nar); all uint32/int32 fields.

    t is the total binary exponent 2^es*K + E (int32); F the fraction field.
    """
    n, es = fmt.bits, fmt.es
    u = _u(jnp.asarray(codes)) & _mask(n)
    is_zero = u == 0
    is_nar = u == (U32(1) << U32(n - 1))
    s = _shr(u, n - 1) & U32(1)
    mag = jnp.where(s == 1, _negate_code(u, n), u)
    body = mag & _mask(n - 1)
    # regime via count-leading-(sign)bits of the body, aligned to 32 bits
    lead = _shr(body, n - 2) & U32(1)
    t_pat = jnp.where(lead == 1, body, (~body) & _mask(n - 1))
    # clz over the n-1 body bits: shift pattern's complement into the top
    r = jnp.minimum(
        _u(jax.lax.clz(_i(_shl((~t_pat) & _mask(n - 1), 32 - (n - 1))))),
        U32(n - 1),
    )
    k = jnp.where(lead == 1, _i(r) - 1, -_i(r))
    rem = jnp.maximum(_i(n - 1) - _i(r) - 1, 0)
    rest = body & _mask(rem)
    e_have = jnp.minimum(rem, es)
    e_field = _shl(_shr(rest, _u(rem - e_have)), _u(es - e_have))
    f_len = jnp.maximum(rem - es, 0)
    f_field = rest & _mask(f_len)
    t = (k << es) + _i(e_field) + fmt.bias
    return s, t, f_len, f_field, is_zero, is_nar


def decode_to_f32(codes, fmt: PositFormat):
    """Posit codes -> float32. Exact for n<=16; RNE on the fraction for n=32."""
    n = fmt.bits
    s, t, f_len, f_field, is_zero, is_nar = _decode_parts(codes, fmt)
    if n <= 16:
        man = _shl(f_field, _u(23 - f_len))  # f_len <= 13 <= 23: exact
        t_adj = t
    else:
        # f_len can reach 27 > 23: RNE into 23 mantissa bits
        cut = jnp.maximum(f_len - 23, 0)
        kept = _shr(f_field, _u(cut))
        guard = _shr(f_field, _u(jnp.maximum(cut - 1, 0))) & U32(1)
        guard = jnp.where(cut > 0, guard, U32(0))
        sticky = (f_field & _mask(jnp.maximum(cut - 1, 0))) != 0
        kept = kept + (guard & (sticky.astype(U32) | (kept & U32(1))))
        carry = _shr(kept, 23) & U32(1)  # mantissa overflow -> bump exponent
        man_full = jnp.where(carry == 1, U32(0), _shl(kept, _u(jnp.maximum(23 - f_len, 0))))
        man = jnp.where(f_len > 23, jnp.where(carry == 1, U32(0), kept & _mask(23)), man_full)
        t_adj = t + _i(carry) * jnp.where(f_len > 23, 1, 0)
    bits = _shl(s, 31) | _shl(_u(t_adj + 127), 23) | man
    val = jax.lax.bitcast_convert_type(bits, jnp.float32)
    val = jnp.where(is_zero, 0.0, val)
    val = jnp.where(is_nar, jnp.nan, val)
    return val


# ---------------------------------------------------------------------------
# Encode
# ---------------------------------------------------------------------------

def _encode_parts(s, t, frac, fw, sticky, is_zero, is_nar, fmt: PositFormat):
    """Assemble a posit code from sign, total exponent t and a fraction field.

    frac: uint32 fraction (value frac/2^fw in [0,1)); fw may be a Python int.
    Bit-exact RNE with guard/sticky; saturates to maxpos/minpos.
    """
    n, es = fmt.bits, fmt.es
    t = t - fmt.bias
    k = t >> es  # arithmetic shift: floor division by 2^es
    e_field = _u(t - (k << es))
    sat_hi = k >= n - 2  # regime fills the body (stop bit cut): >= maxpos
    sat_lo = k <= -(n - 1)
    k_c = jnp.clip(k, -(n - 2), n - 3)
    pos = k_c >= 0
    w0 = jnp.where(pos, k_c + 2, 1 - k_c)
    reg = jnp.where(pos, _shl(_mask(_u(k_c + 1)), 1), U32(1))
    avail = _i(n - 1) - w0
    ef_shift = avail + 1 - es  # fraction bits incl. guard position
    # --- case ef_shift >= 0 ---
    efp = jnp.maximum(ef_shift, 0)
    take = jnp.minimum(_u(efp), U32(fw))         # bits taken from frac
    fbits = _shl(_shr(frac, _u(fw) - take), _u(efp) - take)
    st_a = sticky | ((frac & _mask(_u(fw) - take)) != 0)
    efg_a = _shl(e_field, _u(efp)) | fbits
    # --- case ef_shift < 0 (exponent itself is cut) ---
    cut = _u(jnp.maximum(-ef_shift, 0))
    efg_b = _shr(e_field, cut)
    st_b = sticky | ((e_field & _mask(cut)) != 0) | (frac != 0)
    neg_case = ef_shift < 0
    efg = jnp.where(neg_case, efg_b, efg_a)
    st = jnp.where(neg_case, st_b, st_a)
    guard = efg & U32(1)
    kept = _shr(efg, 1)
    body = _shl(reg, _u(avail)) | kept
    body = body + (guard & (st.astype(U32) | (body & U32(1))))
    body = jnp.where(sat_hi, _mask(n - 1), body)
    body = jnp.where(sat_lo, U32(1), body)
    body = jnp.clip(body, U32(1), _mask(n - 1))  # never round to 0/NaR
    code = jnp.where(s == 1, _negate_code(body, n), body)
    code = jnp.where(is_zero, U32(0), code)
    code = jnp.where(is_nar, U32(1) << U32(n - 1), code)
    return code.astype(fmt.storage_dtype)


def encode_f32(x, fmt: PositFormat):
    """float32 -> posit codes, bit-exact RNE (quantization is exact on the
    float32 value: float32 has 23 fraction bits, all consumed losslessly)."""
    x = jnp.asarray(x, jnp.float32)
    bits = _u(jax.lax.bitcast_convert_type(x, jnp.int32))
    s = _shr(bits, 31)
    exp_raw = _i(_shr(bits, 23) & _mask(8))
    man_raw = bits & _mask(23)
    is_zero = (bits & _mask(31)) == 0
    is_nar = exp_raw == 255  # inf/nan -> NaR
    # subnormals: normalize (value = man * 2^-149)
    subn = (exp_raw == 0) & (~is_zero)
    nz_shift = _u(jax.lax.clz(_i(man_raw))) - U32(8)  # leading zeros within 23 bits
    man_n = jnp.where(subn, _shl(man_raw, nz_shift) & _mask(23), man_raw)
    t = jnp.where(subn, -126 - _i(nz_shift), exp_raw - 127)
    return _encode_parts(s, t, man_n, 23, jnp.zeros_like(is_zero), is_zero, is_nar, fmt)


# ---------------------------------------------------------------------------
# Exact arithmetic (n <= 16; int32 internals)
# ---------------------------------------------------------------------------

_FW = 14  # working fraction bits; >= max f_len (13 for P(16,0))


def _dec_norm(codes, fmt: PositFormat):
    """Decode to (s, t, mant) with mant = 1.f at _FW fraction bits."""
    s, t, f_len, f_field, is_zero, is_nar = _decode_parts(codes, fmt)
    mant = _shl(f_field, _u(_FW - f_len)) | (U32(1) << U32(_FW))
    return s, t, mant, is_zero, is_nar


def mul(a, b, fmt: PositFormat):
    """Exact posit multiply (codes x codes -> codes), n <= 16."""
    if fmt.bits > 16:
        raise NotImplementedError("exact posit arithmetic supports n<=16")
    sa, ta, ma, za, na = _dec_norm(a, fmt)
    sb, tb, mb, zb, nb = _dec_norm(b, fmt)
    s = sa ^ sb
    prod = ma * mb  # < 2^(2FW+2) = 2^30: fits uint32
    hi = _shr(prod, 2 * _FW + 1) & U32(1)
    t = ta + tb + _i(hi) - 2 * fmt.bias  # undo double bias; encode re-adds one
    pn = _shr(prod, hi)  # normalized: [2^{2FW}, 2^{2FW+1})
    frac = pn & _mask(2 * _FW)
    is_zero = za | zb
    is_nar = na | nb
    return _encode_parts(s, t, frac, 2 * _FW, jnp.zeros_like(is_zero), is_zero, is_nar, fmt)


def add(a, b, fmt: PositFormat):
    """Exact posit add (codes x codes -> codes), n <= 16.

    Classic guard/round/sticky alignment; correct RNE per posit_ref oracle
    (verified exhaustively for n=8 and by hypothesis sweeps for n=16).
    """
    if fmt.bits > 16:
        raise NotImplementedError("exact posit arithmetic supports n<=16")
    G = 3  # guard bits
    sa, ta, ma, za, na = _dec_norm(a, fmt)
    sb, tb, mb, zb, nb = _dec_norm(b, fmt)
    swap = (tb > ta) | ((tb == ta) & (mb > ma))
    sl = jnp.where(swap, sb, sa)
    ss = jnp.where(swap, sa, sb)
    tl = jnp.where(swap, tb, ta)
    ts = jnp.where(swap, ta, tb)
    ml = jnp.where(swap, mb, ma)
    ms = jnp.where(swap, ma, mb)
    d = _u(jnp.clip(tl - ts, 0, _FW + G + 2))
    mlg = _shl(ml, G)
    msg_full = _shl(ms, G)
    msg = _shr(msg_full, d)
    sticky = (msg_full & _mask(d)) != 0
    diff_sign = (sl ^ ss) == 1
    mag = jnp.where(diff_sign,
                    _i(mlg) - _i(msg) - jnp.where(sticky, 1, 0),
                    _i(mlg) + _i(msg))
    # For subtraction, borrow the sticky as a -1 so the kept bits stay a
    # *truncation* of the true result; re-express remainder as sticky below.
    res_zero = (mag == 0) & (~sticky)
    mag = jnp.maximum(mag, 1)  # keep clz defined; masked out by res_zero
    # normalize to 1.f at (FW+G) fraction bits
    msb = 31 - jax.lax.clz(mag)  # position of leading 1
    shift = msb - (_FW + G)
    mnorm = jnp.where(shift >= 0, _i(_shr(_u(mag), _u(shift))), _i(_shl(_u(mag), _u(-shift))))
    lost = jnp.where(shift > 0, (_u(mag) & _mask(_u(shift))) != 0, False)
    t = tl + shift - fmt.bias  # one bias gets re-applied in encode
    frac = _u(mnorm) & _mask(_FW + G)
    sticky = sticky | lost
    is_zero = (za & zb) | res_zero
    # one operand zero -> return the other exactly
    only_a = zb & ~za
    only_b = za & ~zb
    is_nar = na | nb
    out = _encode_parts(jnp.where(res_zero, U32(0), sl), t, frac, _FW + G,
                        sticky, is_zero, is_nar, fmt)
    a_c = jnp.asarray(a).astype(fmt.storage_dtype)
    b_c = jnp.asarray(b).astype(fmt.storage_dtype)
    out = jnp.where(only_a, a_c, out)
    out = jnp.where(only_b, b_c, out)
    return out


def sub(a, b, fmt: PositFormat):
    n = fmt.bits
    bu = _u(jnp.asarray(b))
    nb = jnp.where(bu == 0, bu, _negate_code(bu, n))  # -0 == 0; NaR negates to itself
    return add(a, nb.astype(fmt.storage_dtype), fmt)


def fma_f32(acc_f32, a_codes, b_codes, fmt: PositFormat):
    """Decode-multiply-accumulate in f32 (the TPU execution model: posit as
    storage, MXU-style compute)."""
    return acc_f32 + decode_to_f32(a_codes, fmt) * decode_to_f32(b_codes, fmt)


def dot_exact(a_codes, b_codes, fmt: PositFormat):
    """Exact posit dot product: sequential fused decode->mul->add chain in
    posit arithmetic (the TALU-V execution model).  a,b: (..., K) codes."""
    def body(carry, ab):
        ac, bc = ab
        return add(carry, mul(ac, bc, fmt), fmt), None

    a_t = jnp.moveaxis(jnp.asarray(a_codes), -1, 0)
    b_t = jnp.moveaxis(jnp.asarray(b_codes), -1, 0)
    out_shape = jnp.broadcast_shapes(a_t.shape[1:], b_t.shape[1:])
    init = jnp.zeros(out_shape, fmt.storage_dtype)
    out, _ = jax.lax.scan(body, init, (a_t, b_t))
    return out


def matmul_exact(a_codes, b_codes, fmt: PositFormat):
    """(M,K) x (K,N) exact posit matmul (TALU-V semantics, for accuracy
    experiments and small edge kernels)."""
    return dot_exact(a_codes[:, None, :], jnp.swapaxes(b_codes, 0, 1)[None, :, :], fmt)


# public aliases for kernel code (Pallas bodies reuse the same bit helpers)
mask_u32, shl_u32, shr_u32, negate_code_u32 = _mask, _shl, _shr, _negate_code

# convenience jitted entry points ------------------------------------------

decode_to_f32_jit = jax.jit(decode_to_f32, static_argnums=1)
encode_f32_jit = jax.jit(encode_f32, static_argnums=1)
add_jit = jax.jit(add, static_argnums=2)
mul_jit = jax.jit(mul, static_argnums=2)
