"""AdamW with fp32 master weights, global-norm clipping and LR schedules.

Hand-rolled (no optax in this container) but production-shaped: the
optimizer state is a plain pytree ``{"step", "mu", "nu", "master"}`` that
shards exactly like the parameters (FSDP-friendly — every leaf has the same
shape as its param), so the launcher can reuse the param sharding rules.

``master`` holds fp32 copies when the model params are lower precision
(bf16); updates are computed in fp32 and cast back — the standard
mixed-precision recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"   # cosine | linear | constant
    min_lr_ratio: float = 0.1


def make_schedule(cfg: AdamWConfig):
    """step (int32 scalar) -> lr (f32 scalar); warmup + decay."""

    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "linear":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - t)
        else:
            decay = 1.0
        return cfg.lr * warm * decay

    return sched


def adamw_init(params, dtype=jnp.float32, keep_master: bool = True):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": zeros,
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params),
    }
    if keep_master:
        # copy=True: an fp32 param leaf must not ALIAS its master copy
        # (donating both to the jitted step would donate one buffer twice)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, jnp.float32, copy=True), params)
    return state


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """Returns (new_params, new_state, metrics). All math in fp32."""
    step = state["step"] + 1
    if lr is None:
        lr = make_schedule(cfg)(step)
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    master = state.get("master")
    ref = master if master is not None else params

    def upd(p, m, v):
        p32 = p.astype(jnp.float32)
        step_v = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2, standard)
        wd = cfg.weight_decay * p32 if p.ndim >= 2 else 0.0
        return p32 - lr * (step_v + wd)

    new_master = jax.tree.map(upd, ref, mu, nu)
    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                              new_master, params)
    new_state = {"step": step, "mu": mu, "nu": nu}
    if master is not None:
        new_state["master"] = new_master
    metrics = {"grad_norm": gn, "lr": lr}
    return new_params, new_state, metrics
