from .adamw import AdamWConfig, adamw_init, adamw_update, make_schedule
from .compression import compress_grads, decompress_grads, error_feedback_update
