"""Gradient wire compression — the paper's posit format as a collective
wire format (beyond-paper, in the paper's spirit: transprecision applied to
the *communication* datapath instead of the ALU datapath).

Data-parallel all-reduces move ``bytes = params * wire_bits/8`` over ICI;
storing the wire in posit8/posit16 cuts the collective roofline term by
2-4x.  Error feedback (Seide et al. / EF-SGD) keeps the compression
*unbiased over time*: the residual of each quantization is added back into
the next step's gradient, so convergence matches fp32 wire in expectation.

The compress/decompress pair is exact round-trip JAX (posit codec from
``core.posit``), so it runs identically under jit/shard_map; the all-reduce
itself stays XLA-native (psum of decoded values) — on a real fleet the
decoded psum would be replaced by a ring exchange of packed codes, which
``serve/distributed.py`` demonstrates for the decode path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import posit, quant
from ..core.formats import PositFormat, get


def compress_grads(grads, fmt_name: Optional[str], residual=None):
    """Quantize a grad pytree to the wire format with error feedback.

    Returns (wire_pytree, new_residual).  wire leaves are QuantizedTensor
    (packed codes + pow2 scale); residual leaves are fp32 arrays.
    """
    if fmt_name is None:
        return grads, residual
    fmt = get(fmt_name)

    def comp(g, r):
        g32 = g.astype(jnp.float32)
        if r is not None:
            g32 = g32 + r
        qt = quant.quantize(g32, fmt, axis=None)
        deq = quant.dequantize(qt)
        return qt, g32 - deq

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    wires = jax.tree_util.tree_unflatten(tdef, [w for w, _ in out])
    new_res = jax.tree_util.tree_unflatten(tdef, [r for _, r in out])
    return wires, new_res


def decompress_grads(wires):
    """Inverse of compress_grads (without residual): decode to fp32."""
    def dec(leaf):
        if isinstance(leaf, quant.QuantizedTensor):
            return leaf.dequantize(jnp.float32)
        return leaf
    return jax.tree.map(dec, wires,
                        is_leaf=lambda l: isinstance(l, quant.QuantizedTensor))


def error_feedback_update(grads, residual, fmt_name: Optional[str]):
    """One-shot fused compress->decompress with EF; returns
    (decoded_grads, new_residual).  This is what the train step applies just
    before the data-parallel mean so the all-reduce payload is the decoded
    (wire-precision) values."""
    if fmt_name is None:
        return grads, residual
    wires, new_res = compress_grads(grads, fmt_name, residual)
    return decompress_grads(wires), new_res


def wire_bytes(grads, fmt_name: Optional[str]) -> int:
    """Bytes a DP all-reduce moves per step for this wire format."""
    n = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(grads))
    bits = get(fmt_name).bits if fmt_name else 32
    return n * bits // 8
