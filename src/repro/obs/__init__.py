"""Serving observability: span tracing, metrics, time attribution.

* :mod:`repro.obs.tracer` — low-overhead thread-aware span tracer with
  Chrome-trace/Perfetto export (``Tracer``);
* :mod:`repro.obs.metrics` — typed metrics registry (counters, gauges,
  log-bucketed latency histograms) + the ``StatsView`` legacy facade;
* :mod:`repro.obs.report` — per-stage wall-clock attribution
  (``stage_breakdown``) separating host-dispatch from device time;
* :mod:`repro.obs.energy` — modeled joules/token accounting
  (``EnergyAccountant``): loop-aware HLO cost analysis of each compiled
  engine stage priced with the paper's TALU per-MAC PDP row plus a
  documented DRAM pJ/byte constant, multiplied by live per-stage
  invocation counters.
"""
from .energy import EnergyAccountant, StageEnergy, format_energy
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      StatsView)
from .report import format_breakdown, stage_breakdown
from .tracer import Span, Tracer

__all__ = ["Counter", "EnergyAccountant", "Gauge", "Histogram",
           "MetricsRegistry", "StageEnergy", "StatsView", "Span",
           "Tracer", "format_breakdown", "format_energy",
           "stage_breakdown"]
