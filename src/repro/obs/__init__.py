"""Serving observability: span tracing, metrics, time attribution.

* :mod:`repro.obs.tracer` — low-overhead thread-aware span tracer with
  Chrome-trace/Perfetto export (``Tracer``);
* :mod:`repro.obs.metrics` — typed metrics registry (counters, gauges,
  log-bucketed latency histograms) + the ``StatsView`` legacy facade;
* :mod:`repro.obs.report` — per-stage wall-clock attribution
  (``stage_breakdown``) separating host-dispatch from device time.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      StatsView)
from .report import format_breakdown, stage_breakdown
from .tracer import Span, Tracer

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "StatsView", "Span", "Tracer", "format_breakdown",
           "stage_breakdown"]
