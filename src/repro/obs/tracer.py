"""Low-overhead span tracer: context-manager/decorator API, thread-aware,
monotonic-clocked, ring-buffered, Chrome-trace/Perfetto export.

The serving stack (engine stages, orchestrator loop, speculative rounds,
page allocator) opens *spans* around units of work::

    tracer = Tracer(enabled=True)
    with tracer.span("generate.dispatch", cat="engine"):
        out = generate_fn(params, state)

    @tracer.trace("detok", cat="detok")
    def detokenize(...): ...

Design points:

* **Disabled is (nearly) free.**  ``span()`` on a disabled tracer returns
  a shared no-op context manager after one attribute check — no
  allocation, no clock read.  The serving hot loop keeps its spans in
  place permanently and pays < 1 µs/call when tracing is off (bounded by
  ``tests/test_obs.py``).
* **Monotonic clock.**  All stamps are ``time.perf_counter()`` — the
  highest-resolution monotonic clock, system-wide on Linux, so stamps
  compare across threads.  Never ``time.time()`` (not monotonic; NTP
  steps corrupt durations).
* **Thread-aware nesting.**  Each thread keeps its own span stack
  (``threading.local``), so spans nest correctly per thread and a span's
  *self time* (duration minus time spent in child spans) is computed
  online at close.  Self times are the currency of the per-stage wall
  clock attribution in :mod:`repro.obs.report`: summed over all spans of
  one thread they tile the traced wall time exactly — no double counting
  of a stage inside the loop segment that dispatched it.
* **Bounded memory.**  Finished spans land in a ring buffer
  (``collections.deque(maxlen=capacity)``) — old events fall off, but the
  per-name *aggregates* (count / total / self seconds) are exact over the
  whole run regardless of ring capacity.
* **Chrome trace export.**  ``chrome_trace()`` emits the Trace Event
  Format JSON (``ph: "X"`` complete events, µs timestamps, thread-name
  metadata) that ``chrome://tracing`` and https://ui.perfetto.dev load
  directly; engine stages are additionally wrapped in
  ``jax.profiler.TraceAnnotation`` at the call site so host spans line up
  with XLA device traces captured via ``jax.profiler``.
"""
from __future__ import annotations

import functools
import json
import os
import threading
from collections import deque
from time import perf_counter
from typing import Any, Callable, Dict, List, Optional

__all__ = ["Tracer", "Span"]


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()
#: shared no-op span for call sites with no tracer wired at all
NULL_SPAN = _NULL_SPAN


class Span:
    """One live span; use via ``with tracer.span(...)``, not directly."""
    __slots__ = ("_tracer", "name", "cat", "args", "t0", "t1", "_child_s")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._child_s = 0.0

    def __enter__(self) -> "Span":
        self._tracer._stack().append(self)
        self.t0 = perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = perf_counter()
        stack = self._tracer._stack()
        # tolerate misuse (exit out of order) without corrupting siblings
        if stack and stack[-1] is self:
            stack.pop()
        dur = self.t1 - self.t0
        if stack:
            stack[-1]._child_s += dur
        self._tracer._record(self, dur, dur - self._child_s)
        return False


class Tracer:
    """Span recorder: ring buffer of events + exact per-name aggregates."""

    def __init__(self, capacity: int = 65536, enabled: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.enabled = enabled
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        # (name, cat) -> [count, total_s, self_s]; exact even on overflow
        self._agg: Dict[Any, List[float]] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._threads: Dict[int, str] = {}
        self._epoch = perf_counter()
        self._pid = os.getpid()

    # ---- recording ----
    def span(self, name: str, cat: str = "host", **args) -> Any:
        """Open a span; returns a context manager.  No-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, cat, args or None)

    def trace(self, name: Optional[str] = None,
              cat: str = "host") -> Callable:
        """Decorator form: ``@tracer.trace("stage")``."""
        def deco(fn: Callable) -> Callable:
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                if not self.enabled:
                    return fn(*a, **kw)
                with Span(self, label, cat, None):
                    return fn(*a, **kw)
            return wrapper
        return deco

    def record(self, name: str, t0: float, t1: float, cat: str = "host",
               **args) -> None:
        """Record an already-closed span from external ``perf_counter``
        stamps (e.g. a request's queue wait measured between its submit
        and admit stamps).  No stack interaction: the span never nests,
        so its self time equals its duration, and — unlike ``span()`` —
        it does not subtract from any live parent span.  Use ``cat`` to
        pick the attribution bucket (``"queue"`` spans are reported
        outside the wall-clock sum: a request waiting overlaps other
        requests decoding)."""
        if not self.enabled:
            return
        dur = t1 - t0
        tid = threading.get_ident()
        t = threading.current_thread()
        key = (name, cat)
        with self._lock:
            self._threads.setdefault(tid, t.name)
            self._ring.append((name, cat, tid, t0, t1, args or None))
            agg = self._agg.get(key)
            if agg is None:
                self._agg[key] = [1, dur, dur]
            else:
                agg[0] += 1
                agg[1] += dur
                agg[2] += dur

    def _stack(self) -> List[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
            t = threading.current_thread()
            with self._lock:
                self._threads[t.ident] = t.name
        return stack

    def _record(self, span: Span, dur: float, self_s: float) -> None:
        tid = threading.get_ident()
        key = (span.name, span.cat)
        with self._lock:
            self._ring.append((span.name, span.cat, tid, span.t0, span.t1,
                               span.args))
            agg = self._agg.get(key)
            if agg is None:
                self._agg[key] = [1, dur, self_s]
            else:
                agg[0] += 1
                agg[1] += dur
                agg[2] += self_s

    # ---- control ----
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop recorded events and aggregates (enabled flag unchanged)."""
        with self._lock:
            self._ring.clear()
            self._agg.clear()
            self._epoch = perf_counter()

    # ---- inspection / export ----
    def events(self) -> List[Dict[str, Any]]:
        """Finished spans still in the ring buffer, oldest first."""
        with self._lock:
            raw = list(self._ring)
        return [{"name": n, "cat": c, "tid": tid, "t0": t0, "t1": t1,
                 "args": args} for n, c, tid, t0, t1, args in raw]

    def self_times(self) -> Dict[str, Dict[str, Any]]:
        """Exact per-span-name aggregates over the whole run:
        ``{name: {cat, count, total_s, self_s}}``.  ``self_s`` excludes
        time spent inside child spans, so summing it across names never
        double-counts nested work."""
        with self._lock:
            items = list(self._agg.items())
        out: Dict[str, Dict[str, Any]] = {}
        for (name, cat), (count, total, self_s) in items:
            rec = out.get(name)
            if rec is None:
                out[name] = {"cat": cat, "count": int(count),
                             "total_s": total, "self_s": self_s}
            else:                      # same name under two cats: merge
                rec["count"] += int(count)
                rec["total_s"] += total
                rec["self_s"] += self_s
        return out

    def chrome_trace(self) -> Dict[str, Any]:
        """Trace Event Format dict (load in chrome://tracing / Perfetto)."""
        events: List[Dict[str, Any]] = []
        with self._lock:
            raw = list(self._ring)
            threads = dict(self._threads)
            epoch = self._epoch
        for name, cat, tid, t0, t1, args in raw:
            ev: Dict[str, Any] = {
                "name": name, "cat": cat, "ph": "X", "pid": self._pid,
                "tid": tid, "ts": (t0 - epoch) * 1e6,
                "dur": (t1 - t0) * 1e6}
            if args:
                ev["args"] = args
            events.append(ev)
        for tid, tname in threads.items():
            events.append({"name": "thread_name", "ph": "M",
                           "pid": self._pid, "tid": tid,
                           "args": {"name": tname}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
