"""Per-stage wall-clock attribution from a span trace.

Turns a :class:`~repro.obs.tracer.Tracer`'s exact self-time aggregates
into the breakdown the benchmarks publish in ``BENCH_*.json``: for each
engine stage (prefill / insert / generate / verify / rollback, plus the
``draft.``-prefixed speculative draft stages) the **host-dispatch** time
(Python + jit dispatch until the stage call returns) and the **device**
time (the ``jax.block_until_ready`` wait that follows), plus the
explicitly measured host buckets (sampling, orchestrator segments,
allocator work) and the unattributed remainder.

Because the inputs are per-span *self* times (child spans subtracted,
see ``Tracer.self_times``), the buckets are disjoint by construction on
each thread: summing them never double-counts a ``generate`` dispatch
inside the ``orch.step`` loop segment that issued it.  Spans from the
detokenizer thread run concurrently with the scheduler and are reported
separately (``concurrent``), outside the wall-clock sum.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

# span categories whose work overlaps the scheduler thread rather than
# partitioning it (reported, but excluded from the attribution sum)
CONCURRENT_CATS = ("detok",)
# per-request wait categories: a request queue-waiting overlaps other
# requests' decode wall time, so the bucket is reported on its own
# (summable against per-request admit-submit stamps) but never added to
# the attribution sum — it would double-count the decode work it overlaps
QUEUE_CATS = ("queue",)

__all__ = ["stage_breakdown", "format_breakdown"]


def _sub(cur: Dict[str, Any], base: Optional[Dict[str, Any]]):
    """Aggregate delta ``cur - base`` (for windowed breakdowns)."""
    if not base:
        return cur
    out = {}
    for name, rec in cur.items():
        b = base.get(name)
        if b is None:
            out[name] = dict(rec)
            continue
        d = {"cat": rec["cat"], "count": rec["count"] - b["count"],
             "total_s": rec["total_s"] - b["total_s"],
             "self_s": rec["self_s"] - b["self_s"]}
        if d["count"] > 0 or d["total_s"] > 1e-12:
            out[name] = d
    return out


def stage_breakdown(tracer, wall_s: float, *,
                    since: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Attribute ``wall_s`` seconds of serving to stages and host buckets.

    ``since`` is an earlier ``tracer.self_times()`` snapshot; passing it
    restricts the breakdown to the window since that snapshot (used by
    the load-sweep bench to keep one trace per run but one breakdown per
    load point).

    Returns::

        {"wall_s": ..., "stages": {stage: {"dispatch_s", "device_s",
         "calls"}}, "host": {bucket: seconds}, "concurrent": {...},
         "queue": {span: {"total_s", "count"}},
         "attributed_s": ..., "unattributed_s": ...,
         "attributed_frac": ...}

    ``queue`` holds per-request wait spans (``cat="queue"``, recorded by
    the engine from submit→admit stamps): summed seconds and span count
    per name, outside the attribution sum — N queued requests wait
    concurrently with each other and with the decode work the other
    buckets already cover, so adding them would overcount the wall.
    """
    agg = _sub(tracer.self_times(), since)
    stages: Dict[str, Dict[str, float]] = {}
    host: Dict[str, float] = {}
    concurrent: Dict[str, float] = {}
    queue: Dict[str, Dict[str, float]] = {}
    attributed = 0.0
    for name, rec in agg.items():
        if rec["cat"] == "engine":
            stage, _, kind = name.rpartition(".")
            s = stages.setdefault(stage, {"dispatch_s": 0.0,
                                          "device_s": 0.0, "calls": 0})
            if kind == "dispatch":
                s["dispatch_s"] += rec["self_s"]
                s["calls"] += rec["count"]
            else:
                s["device_s"] += rec["self_s"]
            attributed += rec["self_s"]
        elif rec["cat"] in QUEUE_CATS:
            q = queue.setdefault(name, {"total_s": 0.0, "count": 0})
            q["total_s"] += rec["total_s"]
            q["count"] += rec["count"]
        elif rec["cat"] in CONCURRENT_CATS:
            concurrent[name] = concurrent.get(name, 0.0) + rec["self_s"]
        else:
            host[name] = host.get(name, 0.0) + rec["self_s"]
            attributed += rec["self_s"]
    wall_s = max(wall_s, 1e-12)
    # spans can marginally overrun the measured wall window (e.g. the
    # orchestrator polls on either side of it); clamp the remainder at 0
    unattributed = max(wall_s - attributed, 0.0)
    return {"wall_s": wall_s,
            "stages": {k: {"dispatch_s": v["dispatch_s"],
                           "device_s": v["device_s"],
                           "calls": int(v["calls"])}
                       for k, v in sorted(stages.items())},
            "host": dict(sorted(host.items())),
            "concurrent": dict(sorted(concurrent.items())),
            "queue": {k: {"total_s": v["total_s"], "count": int(v["count"])}
                      for k, v in sorted(queue.items())},
            "attributed_s": attributed,
            "unattributed_s": unattributed,
            "attributed_frac": min(attributed / wall_s, 1.0)}


def format_breakdown(bd: Dict[str, Any]) -> str:
    """Human-readable table of a :func:`stage_breakdown` result."""
    wall = bd["wall_s"]
    lines = [f"{'stage':<22s} {'dispatch':>10s} {'device':>10s} "
             f"{'calls':>7s} {'% wall':>7s}"]
    for name, s in bd["stages"].items():
        tot = s["dispatch_s"] + s["device_s"]
        lines.append(f"{name:<22s} {s['dispatch_s'] * 1e3:>8.1f}ms "
                     f"{s['device_s'] * 1e3:>8.1f}ms {s['calls']:>7d} "
                     f"{100 * tot / wall:>6.1f}%")
    for name, v in bd["host"].items():
        lines.append(f"{name:<22s} {v * 1e3:>8.1f}ms {'':>10s} {'':>7s} "
                     f"{100 * v / wall:>6.1f}%")
    for name, v in bd["concurrent"].items():
        lines.append(f"{name + ' (conc.)':<22s} {v * 1e3:>8.1f}ms")
    for name, q in bd.get("queue", {}).items():
        lines.append(f"{name + ' (queue)':<22s} {q['total_s'] * 1e3:>8.1f}ms"
                     f" {'':>10s} {q['count']:>7d}")
    lines.append(f"{'(unattributed)':<22s} "
                 f"{bd['unattributed_s'] * 1e3:>8.1f}ms {'':>10s} {'':>7s} "
                 f"{100 * bd['unattributed_s'] / wall:>6.1f}%")
    lines.append(f"attributed {100 * bd['attributed_frac']:.1f}% of "
                 f"{wall * 1e3:.1f}ms wall")
    return "\n".join(lines)
