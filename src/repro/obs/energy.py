"""Energy-per-token accounting for the serving engine stages.

The paper's headline claims are *energy* numbers (54.6x power, 1.98x
vector energy efficiency), so the serving telemetry must report
joules/token next to tok/s — this module closes that gap without any
power instrumentation, as a *model*:

1. **Static pJ-per-invocation table.**  Every jitted engine stage
   (prefill / insert / generate / verify / rollback, plus the ``draft.``
   speculative stages) records its first-seen abstract arg spec in
   ``TransprecisionEngine.stage_specs``.  The accountant re-lowers the
   stage from that spec, runs the loop-aware HLO cost analysis
   (:mod:`repro.launch.hlo_cost`) on the compiled program for FLOPs and
   HBM bytes split by dtype, and prices them:

   * **compute** — MACs (dot/conv FLOPs / 2, the ``mac_flops`` split of
     the cost analysis) times a per-MAC PDP from the paper's TALU row
     (:func:`benchmarks.hwmodel.pj_per_mac`: 38.9/43.44/46.15 pJ at
     8/16/32 bit), weighted by the stage's *format mix* — the fraction
     of MAC work each ``TCPolicy`` role format carries, estimated from
     the weight-leaf element counts in the stage spec (matmul FLOPs are
     proportional to weight size x batch).  Deliberately NOT total
     FLOPs: the compiled program fake-quantizes weights in-graph (QAT
     emulation), and those elementwise decode flops — up to 10x the
     real MACs for posit-packed weights — are work the transprecision
     ALU performs natively inside its MAC datapath, already covered by
     the PDP constant.  Vector ops (softmax, norms) are second-order
     and likewise not priced;
   * **memory** — modeled off-chip traffic times :data:`benchmarks
     .hwmodel.DRAM_PJ_PER_BYTE`: the stage's ENTRY parameter bytes
     (weights + decode state + activations in, i.e. one fetch per
     invocation — a weight-stationary refinement is a knob, not a
     different model), with the weight buffers re-priced at their
     *policy storage width*: the program reads f32 weights and
     fake-quantizes in-graph, but the modeled edge deployment stores
     them packed (``core.quant``), so a posit8-weight stage fetches
     bits/32 of the f32 bytes.  Posit-packed KV code buffers need no
     such adjustment — they are physically ``u8``/``u16`` program
     inputs and show up at their true width (cross-checked against
     ``kv_cache_bytes`` in ``tests/test_energy.py``).  Fusion-boundary
     HBM bytes from the HLO analysis are reported per stage
     (``hbm_bytes``) for reference but are not DRAM-priced: fusion
     intermediates live in on-chip SRAM, and the QAT emulation inflates
     them with decoded-weight buffers the edge device never writes.

2. **Live multipliers.**  The metrics registry counts every stage
   invocation (``stage.<name>.calls``, always on); joules are the static
   table times those counters, so windowed readings (per bench load
   point) are just counter deltas.

The table is deterministic: same config + policy + shapes -> same HLO ->
same pJ (asserted in tests), and it is memoized process-wide so a bench
sweep prices each distinct stage program once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.formats import get as get_format
from ..core.transprecision import _ROLE_BY_NAME
from ..launch.hlo_cost import analyze, entry_param_bytes_by_dtype

try:                      # benchmarks/ is a sibling of src/ on sys.path
    from benchmarks.hwmodel import DRAM_PJ_PER_BYTE, pj_per_mac
except ImportError:       # pragma: no cover - installed-package layout
    DRAM_PJ_PER_BYTE = 20.0
    _TALU_PDP_PJ = (38.9, 43.44, 46.15)   # paper Table IV (pinned to
                                          # hwmodel in tests/test_energy)

    def pj_per_mac(bits: int) -> float:
        return _TALU_PDP_PJ[0 if bits <= 8 else 1 if bits <= 16 else 2]

__all__ = ["StageEnergy", "EnergyAccountant", "format_energy"]

# weight-leaf name -> policy role, extended with the embedding/readout
# leaves pack_params leaves alone (they still burn MACs in the logits
# matmul, at the embed_weights role's format)
_ENERGY_ROLE_BY_NAME = dict(_ROLE_BY_NAME,
                            embed="embed_weights", lm_head="embed_weights")


@dataclasses.dataclass
class StageEnergy:
    """Static per-invocation energy of one compiled engine stage."""
    stage: str
    flops: float                # total HLO flops (incl. QAT emulation)
    mac_flops: float            # dot/conv share: the priced MACs
    hbm_bytes: float            # fusion-boundary HLO bytes (reference)
    model_bytes: float          # DRAM-priced: entry params, packed wts
    bytes_by_dtype: Dict[str, float]
    param_bytes_by_dtype: Dict[str, float]
    mac_mix: Dict[str, Dict[str, float]]   # fmt -> {bits, frac}
    pj_compute: float
    pj_memory: float

    @property
    def pj_total(self) -> float:
        return self.pj_compute + self.pj_memory

    def as_dict(self) -> Dict[str, Any]:
        return {
            "flops": self.flops,
            "mac_flops": self.mac_flops,
            "hbm_bytes": self.hbm_bytes,
            "model_bytes": self.model_bytes,
            "bytes_by_dtype": {k: v for k, v in
                               sorted(self.bytes_by_dtype.items())},
            "mac_mix": {k: {"bits": int(v["bits"]),
                            "frac": round(v["frac"], 4)}
                        for k, v in sorted(self.mac_mix.items())},
            "pj_compute": self.pj_compute,
            "pj_memory": self.pj_memory,
            "pj_per_call": self.pj_total,
        }


def _leaf_name(kp) -> Optional[str]:
    for k in reversed(kp):
        key = str(getattr(k, "key", getattr(k, "idx", k)))
        if not key.isdigit():
            return key
    return None


def _weight_info(spec, policy) -> Tuple[Dict[str, Dict[str, float]],
                                        float, float]:
    """(mac_mix, full_weight_bytes, packed_weight_bytes) from a stage's
    abstract arg spec: weight leaves classified by name -> policy role ->
    format; MAC share per format estimated by element count."""
    weights: List[Tuple[str, Any]] = []

    def visit(kp, leaf):
        if not hasattr(leaf, "shape") or getattr(leaf, "ndim", 0) < 2:
            return
        name = _leaf_name(kp)
        role = _ENERGY_ROLE_BY_NAME.get(name)
        if role is not None:
            weights.append((role, leaf))

    jax.tree_util.tree_map_with_path(visit, spec)
    by_fmt: Dict[str, Dict[str, float]] = {}
    w_full = w_packed = 0.0
    total_elems = 0.0
    for role, leaf in weights:
        elems = float(np.prod(leaf.shape))
        itemsize = np.dtype(leaf.dtype).itemsize
        fmt = policy.fmt_for(role)
        if fmt is None:
            bits = itemsize * 8
            label = {2: "bf16", 4: "f32"}.get(itemsize, f"int{bits}")
        else:
            bits = get_format(fmt).bits
            label = fmt
        rec = by_fmt.setdefault(label, {"bits": float(bits), "elems": 0.0})
        rec["elems"] += elems
        total_elems += elems
        w_full += elems * itemsize
        w_packed += elems * bits / 8.0
    mix = {}
    for label, rec in by_fmt.items():
        mix[label] = {"bits": rec["bits"],
                      "frac": rec["elems"] / max(total_elems, 1.0)}
    return mix, w_full, w_packed


# process-wide memo of the expensive half (lower + compile + parse),
# keyed by everything that determines the stage's compiled program
_COST_CACHE: Dict[str, Dict[str, Any]] = {}


def _spec_key(cfg, policy, stage: str, spec) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    sig = ";".join(
        f"{getattr(l, 'dtype', '')}/{getattr(l, 'shape', l)}"
        for l in leaves)
    return f"{getattr(cfg, 'name', cfg)}|{policy.name}|{stage}|" \
           f"{treedef}|{sig}"


def _stage_cost(cfg, policy, stage: str, fn, spec) -> Dict[str, Any]:
    key = _spec_key(cfg, policy, stage, spec)
    cached = _COST_CACHE.get(key)
    if cached is None:
        txt = fn.lower(*spec).compile().as_text()
        cached = _COST_CACHE[key] = {
            "analysis": analyze(txt),
            "param_bytes": entry_param_bytes_by_dtype(txt)}
    return cached


class EnergyAccountant:
    """Joules accounting over a serving driver's engine stages.

    ``driver`` is a ``ServingEngine`` / ``SpeculativeEngine`` (stages
    found via ``.engine`` and ``.draft_engine``) or a bare
    ``TransprecisionEngine``.  The pJ table is built lazily on first
    use from whatever stages have run by then; per-window joules come
    from ``calls_snapshot()`` deltas.
    """

    def __init__(self, driver, *,
                 dram_pj_per_byte: float = DRAM_PJ_PER_BYTE):
        self.driver = driver
        self.metrics = getattr(driver, "metrics", None)
        self.dram_pj_per_byte = float(dram_pj_per_byte)
        self._table: Dict[str, StageEnergy] = {}
        self._errors: Dict[str, str] = {}

    def _engines(self) -> List[Any]:
        if hasattr(self.driver, "stage_specs"):
            return [self.driver]
        out = [self.driver.engine]
        draft = getattr(self.driver, "draft_engine", None)
        if draft is not None:
            out.append(draft)
        return out

    # ---- static table ----
    def table(self) -> Dict[str, StageEnergy]:
        """pJ-per-invocation per stage name (lazily built, memoized)."""
        for eng in self._engines():
            for name, (fn, spec) in list(eng.stage_specs.items()):
                if name in self._table or name in self._errors:
                    continue
                try:
                    self._table[name] = self._price_stage(eng, name, fn,
                                                          spec)
                except Exception as e:   # never fail serving over a cost
                    self._errors[name] = f"{type(e).__name__}: {e}"
        return self._table

    def _price_stage(self, eng, name: str, fn, spec) -> StageEnergy:
        cost = _stage_cost(eng.cfg, eng.policy, name, fn, spec)
        ana = cost["analysis"]
        flops, hbm = float(ana["flops"]), float(ana["bytes"])
        macs = float(ana["mac_flops"]) / 2.0
        mix, w_full, w_packed = _weight_info(spec, eng.policy)
        if mix:
            pj_mac = sum(v["frac"] * pj_per_mac(int(v["bits"]))
                         for v in mix.values())
        else:                       # no MAC weights (insert/rollback):
            pj_mac = pj_per_mac(32)  # stray MACs priced at full width
        # DRAM-priced traffic: one fetch of every entry parameter per
        # invocation, weights re-priced from the program's f32 to the
        # policy's packed storage width; floored at the packed bytes so
        # the adjustment can never go negative
        param_bytes = float(sum(cost["param_bytes"].values()))
        model_bytes = (max(param_bytes - w_full + w_packed, w_packed)
                       if w_full > 0 else param_bytes)
        return StageEnergy(
            stage=name, flops=flops, mac_flops=float(ana["mac_flops"]),
            hbm_bytes=hbm, model_bytes=model_bytes,
            bytes_by_dtype=dict(ana["bytes_by_dtype"]),
            param_bytes_by_dtype=dict(cost["param_bytes"]),
            mac_mix=mix,
            pj_compute=macs * pj_mac,
            pj_memory=model_bytes * self.dram_pj_per_byte)

    # ---- live multipliers ----
    def calls_snapshot(self) -> Dict[str, int]:
        """Current per-stage invocation counts from the registry."""
        if self.metrics is None:
            return {}
        counters = self.metrics.snapshot()["counters"]
        out = {}
        for cname, v in counters.items():
            if cname.startswith("stage.") and cname.endswith(".calls"):
                out[cname[len("stage."):-len(".calls")]] = int(v)
        return out

    @staticmethod
    def calls_delta(now: Dict[str, int],
                    before: Dict[str, int]) -> Dict[str, int]:
        return {k: v - before.get(k, 0) for k, v in now.items()
                if v - before.get(k, 0) > 0}

    def _tokens_now(self) -> int:
        if self.metrics is None:
            return 0
        return int(self.metrics.snapshot()["counters"]
                   .get("engine.tokens", 0))

    # ---- joules ----
    def breakdown(self, *, calls: Optional[Dict[str, int]] = None,
                  tokens: Optional[int] = None) -> Dict[str, Any]:
        """Joules attribution: cumulative by default, windowed when
        ``calls`` (a :meth:`calls_delta`) and ``tokens`` are given.
        Cumulative calls also publish ``energy.joules_total`` /
        ``energy.joules_per_token`` gauges to the registry."""
        cumulative = calls is None
        if calls is None:
            calls = self.calls_snapshot()
        if tokens is None:
            tokens = self._tokens_now()
        table = self.table()
        stages: Dict[str, Any] = {}
        joules = 0.0
        for name, e in sorted(table.items()):
            n = int(calls.get(name, 0))
            j = n * e.pj_total * 1e-12
            joules += j
            stages[name] = {**e.as_dict(), "calls": n, "joules": j}
        jpt = joules / tokens if tokens else None
        out = {"joules_total": joules,
               "tokens": int(tokens),
               "joules_per_token": jpt,
               "tok_per_joule": tokens / joules if joules > 0 else None,
               "model": {"mac_pdp": "TALU Table IV "
                                    "(benchmarks/hwmodel.py pj_per_mac)",
                         "dram_pj_per_byte": self.dram_pj_per_byte},
               "stages": stages}
        if self._errors:
            out["errors"] = dict(self._errors)
        if cumulative and self.metrics is not None:
            self.metrics.gauge("energy.joules_total").set(joules)
            if jpt is not None:
                self.metrics.gauge("energy.joules_per_token").set(jpt)
        return out


def format_energy(bd: Dict[str, Any]) -> str:
    """Human-readable table of a :meth:`EnergyAccountant.breakdown`."""
    lines = []
    jpt = bd["joules_per_token"]
    tpj = bd["tok_per_joule"]
    head = f"energy: {bd['joules_total'] * 1e3:.3f} mJ over " \
           f"{bd['tokens']} tokens"
    if jpt is not None:
        head += f" -> {jpt * 1e6:.1f} uJ/token ({tpj:.0f} tok/J)"
    lines.append(head)
    lines.append(f"  {'stage':<16s} {'calls':>7s} {'uJ/call':>9s} "
                 f"{'compute%':>9s}  mac mix")
    for name, s in bd["stages"].items():
        tot = s["pj_per_call"]
        comp = 100.0 * s["pj_compute"] / tot if tot else 0.0
        mix = " ".join(f"{k}:{v['frac']:.2f}"
                       for k, v in s["mac_mix"].items()) or "-"
        lines.append(f"  {name:<16s} {s['calls']:>7d} "
                     f"{tot * 1e-6:>9.2f} {comp:>8.1f}%  {mix}")
    for name, err in bd.get("errors", {}).items():
        lines.append(f"  {name:<16s} (not priced: {err})")
    return "\n".join(lines)
