"""Typed metrics registry: counters, gauges, log-bucketed histograms.

One shared, snapshot-able registry replaces the divergent ad-hoc
``self.stats`` dicts that used to live in ``ServingEngine``,
``SpeculativeEngine`` and ``Orchestrator``:

* ``Counter`` — monotonically increasing event count (``inc``); ``set``
  exists for benchmark warmup resets.
* ``Gauge`` — last-written value (queue depth, live pages, cache bytes).
* ``Histogram`` — log-bucketed latency distribution.  Buckets are
  geometric (ratio ``2**(1/8)`` by default, ~9 % wide), so p50/p95/p99
  come out within one bucket width of the exact sample percentile at any
  scale from sub-µs to hours while storing only a sparse dict of bucket
  counts; exact ``count``/``sum``/``min``/``max`` ride along.
* ``MetricsRegistry`` — typed get-or-create by name (requesting an
  existing name as a different type raises), JSON-able ``snapshot()``
  and exact ``from_snapshot`` round-trip.
* ``StatsView`` — a MutableMapping facade that maps the engines' legacy
  ``stats["tokens"]``-style keys onto registry metrics, so every
  pre-existing test, bench and caller keeps working while the registry
  is the single source of truth (``scripts/stats_consistency.py`` pins
  the equivalence in CI).

Thread safety: mutations take a per-metric lock; all operations are
cheap enough for the serving hot loop (a counter ``inc`` is the same
order as the dict update it replaced).
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, Iterator, List, MutableMapping, Optional, Union

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView"]

Number = Union[int, float]


class Counter:
    """Monotonic event counter (``set`` only for explicit resets)."""
    __slots__ = ("name", "_v", "_lock")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self._v: Number = 0
        self._lock = threading.Lock()

    def inc(self, n: Number = 1) -> None:
        with self._lock:
            self._v += n

    def set(self, v: Number) -> None:
        with self._lock:
            self._v = v

    @property
    def value(self) -> Number:
        return self._v


class Gauge:
    """Last-written value."""
    __slots__ = ("name", "_v", "_lock")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self._v: Number = 0
        self._lock = threading.Lock()

    def set(self, v: Number) -> None:
        with self._lock:
            self._v = v

    def inc(self, n: Number = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> Number:
        return self._v


class Histogram:
    """Log-bucketed histogram with percentile estimation.

    Bucket ``i`` covers ``[lo * ratio**i, lo * ratio**(i+1))``; values
    below ``lo`` (including 0) land in bucket -1, values past the top in
    the last bucket.  Percentiles interpolate within the bucket in log
    space and clamp to the exact observed [min, max], so the relative
    error is bounded by one bucket width (~``ratio - 1``)."""

    kind = "histogram"

    def __init__(self, name: str, lo: float = 1e-7, hi: float = 1e4,
                 ratio: float = 2.0 ** 0.125):
        if not (0 < lo < hi) or ratio <= 1:
            raise ValueError(f"bad histogram bounds lo={lo} hi={hi} "
                             f"ratio={ratio}")
        self.name = name
        self.lo, self.hi, self.ratio = lo, hi, ratio
        self._log_lo = math.log(lo)
        self._log_ratio = math.log(ratio)
        self._nbuckets = int(math.ceil((math.log(hi) - self._log_lo)
                                       / self._log_ratio))
        self._buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._lock = threading.Lock()

    def _index(self, x: float) -> int:
        if x < self.lo:
            return -1
        i = int((math.log(x) - self._log_lo) / self._log_ratio)
        return min(i, self._nbuckets - 1)

    def observe(self, x: Number) -> None:
        x = float(x)
        i = self._index(x)
        with self._lock:
            self._buckets[i] = self._buckets.get(i, 0) + 1
            self.count += 1
            self.sum += x
            if self.min is None or x < self.min:
                self.min = x
            if self.max is None or x > self.max:
                self.max = x

    def percentile(self, q: float) -> Optional[float]:
        """Approximate q-th percentile (q in [0, 100])."""
        with self._lock:
            if not self.count:
                return None
            buckets = sorted(self._buckets.items())
            count, mn, mx = self.count, self.min, self.max
        target = q / 100.0 * count
        seen = 0
        for i, c in buckets:
            if seen + c >= target:
                if i < 0:               # sub-lo bucket: all we know is < lo
                    return max(min(self.lo, mx), mn)
                # interpolate in log space within the bucket
                frac = (target - seen) / c
                log_v = (self._log_lo + (i + frac) * self._log_ratio)
                return min(max(math.exp(log_v), mn), mx)
            seen += c
        return mx

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            buckets = sorted(self._buckets.items())
            snap = {"count": self.count, "sum": self.sum,
                    "min": self.min, "max": self.max}
        snap.update(p50=self.percentile(50), p95=self.percentile(95),
                    p99=self.percentile(99),
                    buckets=[[i, c] for i, c in buckets],
                    lo=self.lo, hi=self.hi, ratio=self.ratio)
        return snap


class MetricsRegistry:
    """Typed, snapshot-able collection of named metrics."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, not {cls.kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """Plain JSON-able dict: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: {count, sum, min, max, p50, p95, p99,
        buckets, ...}}}``."""
        with self._lock:
            metrics = list(self._metrics.values())
        out: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
        for m in metrics:
            if isinstance(m, Counter):
                out["counters"][m.name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][m.name] = m.value
            else:
                out["histograms"][m.name] = m.snapshot()
        return out

    @classmethod
    def from_snapshot(cls, snap: Dict[str, Any]) -> "MetricsRegistry":
        """Rebuild a registry whose ``snapshot()`` equals ``snap`` (the
        round-trip is exact: histogram percentiles are derived from the
        restored bucket counts and min/max)."""
        reg = cls()
        for name, v in snap.get("counters", {}).items():
            reg.counter(name).set(v)
        for name, v in snap.get("gauges", {}).items():
            reg.gauge(name).set(v)
        for name, h in snap.get("histograms", {}).items():
            m = reg.histogram(name, lo=h.get("lo", 1e-7),
                              hi=h.get("hi", 1e4),
                              ratio=h.get("ratio", 2.0 ** 0.125))
            m.count = h["count"]
            m.sum = h["sum"]
            m.min = h["min"]
            m.max = h["max"]
            m._buckets = {int(i): int(c) for i, c in h.get("buckets", [])}
        return reg


class StatsView(MutableMapping):
    """Legacy ``stats`` facade over registry metrics.

    Engine code used to keep ``self.stats = {"tokens": 0, ...}``; tests,
    benches and launchers read (and occasionally reset) those keys.  A
    StatsView keeps that exact surface — ``stats["tokens"] += n``,
    ``stats.get("evictions", 0)``, ``stats.update(tokens=0)``,
    ``{**stats}`` — while each key is backed by a registry Counter or
    Gauge, so there is exactly one copy of every statistic."""

    def __init__(self, registry: MetricsRegistry, prefix: str = ""):
        self._registry = registry
        self._prefix = prefix
        self._bound: Dict[str, Any] = {}

    def bind(self, key: str, metric) -> None:
        """Expose registry ``metric`` under legacy ``key``."""
        self._bound[key] = metric

    def bind_counters(self, *keys: str) -> None:
        for k in keys:
            self.bind(k, self._registry.counter(self._prefix + k))

    def bind_gauges(self, *keys: str) -> None:
        for k in keys:
            self.bind(k, self._registry.gauge(self._prefix + k))

    def metric_name(self, key: str) -> str:
        """Registry name backing legacy ``key`` (for consistency checks)."""
        return self._bound[key].name

    def __getitem__(self, key: str) -> Number:
        return self._bound[key].value

    def __setitem__(self, key: str, value: Number) -> None:
        m = self._bound.get(key)
        if m is None:                      # late keys default to gauges
            m = self._registry.gauge(self._prefix + key)
            self._bound[key] = m
        m.set(value)

    def __delitem__(self, key: str) -> None:
        del self._bound[key]               # unbinds the view only

    def __iter__(self) -> Iterator[str]:
        return iter(self._bound)

    def __len__(self) -> int:
        return len(self._bound)

    def __repr__(self) -> str:
        return f"StatsView({dict(self)})"
