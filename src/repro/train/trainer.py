"""Trainer: checkpointed, fault-tolerant training loop.

Composes the substrate: deterministic data pipeline + jitted train step +
CheckpointManager (atomic/keep-k/async) + fault-tolerance hooks.  The loop
is restart-idempotent: state lives in (checkpoint, step); batches are
regenerated from the step index; a crash at any point resumes bit-exact
(tested in tests/test_system.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..core.transprecision import BF16, TCPolicy, get_policy
from ..data.pipeline import SyntheticLM, make_pipeline
from ..models import lm
from ..optim import AdamWConfig
from .fault_tolerance import CrashBarrier, HeartbeatMonitor, StragglerMitigator
from .step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    checkpoint_keep: int = 3
    async_checkpoint: bool = True
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: lm.ModelCfg, tcfg: TrainerConfig,
                 opt_cfg: Optional[AdamWConfig] = None,
                 policy: TCPolicy = BF16,
                 data: Optional[SyntheticLM] = None,
                 crash_barrier: Optional[CrashBarrier] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or AdamWConfig(total_steps=tcfg.steps)
        self.policy = get_policy(policy)
        self.data = data or make_pipeline(
            cfg, global_batch=tcfg.global_batch, seq_len=tcfg.seq_len,
            seed=tcfg.seed)
        self.step_fn = jax.jit(make_train_step(cfg, self.opt_cfg, self.policy),
                               donate_argnums=0)
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir,
                                       keep=tcfg.checkpoint_keep)
                     if tcfg.checkpoint_dir else None)
        self.monitor = HeartbeatMonitor(n_hosts=1)
        self.mitigator = StragglerMitigator()
        self.crash_barrier = crash_barrier
        self.history: list = []

    # ---- state ----
    def init_state(self) -> TrainState:
        return init_train_state(jax.random.PRNGKey(self.tcfg.seed), self.cfg,
                                self.opt_cfg, self.policy)

    def restore_or_init(self) -> tuple:
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            template = jax.tree.map(
                lambda l: np.zeros(l.shape, l.dtype),
                jax.eval_shape(self.init_state))
            state, meta = self.ckpt.restore(template)
            state = jax.tree.map(jax.numpy.asarray, state)
            return state, int(meta["step"])
        return self.init_state(), 0

    # ---- loop ----
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        state, start = self.restore_or_init()
        steps = steps if steps is not None else self.tcfg.steps
        metrics = {}
        for step in range(start, steps):
            t0 = time.time()
            if self.crash_barrier is not None:
                self.crash_barrier.check(step)
            batch = self.data(step)
            state, metrics = self.step_fn(state, batch)
            dt = time.time() - t0
            self.monitor.beat(0, step, dt)
            self.mitigator.observe(dt)
            if (step + 1) % self.tcfg.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": step + 1, **m, "s_per_step": dt})
                print(f"step {step + 1}: loss={m.get('loss', 0):.4f} "
                      f"lr={m.get('lr', 0):.2e} "
                      f"gnorm={m.get('grad_norm', 0):.3f} ({dt:.2f}s)")
            if (self.ckpt is not None
                    and (step + 1) % self.tcfg.checkpoint_every == 0):
                self.ckpt.save(state, step + 1,
                               blocking=not self.tcfg.async_checkpoint)
        if self.ckpt is not None:
            self.ckpt.save(state, steps, blocking=True)
        return {"state": state,
                "metrics": {k: float(v) for k, v in metrics.items()},
                "history": self.history}
