"""Fault-tolerance primitives for 1000+-node fleets.

On a real multi-pod deployment these hooks bind to the cluster coordinator
(GKE/Borg preemption signals, ICI link health, per-host heartbeats).  Here
they are implemented against process-local signals with the same interfaces
so the Trainer's recovery logic is real and testable:

* ``HeartbeatMonitor``   — tracks per-host step-completion times; flags
                           stragglers at mean + k*sigma and dead hosts at a
                           hard timeout.  At scale this feeds the elastic
                           rescale decision.
* ``StragglerMitigator`` — policy object: deadline-based step skipping
                           (synchronous-with-backup semantics).  Because the
                           data pipeline is step-deterministic, a skipped
                           host replays the exact batch after recovery.
* ``ElasticPlan``        — recomputes (host -> data-shard) assignments for a
                           new world size; with the deterministic pipeline
                           this is a pure function, no data is lost.
* ``CrashBarrier``       — context manager that converts an injected fault
                           into a checkpoint-restore cycle (used by tests to
                           prove restart-exactness).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HostStatus:
    last_beat: float
    last_step: int
    step_times: List[float] = dataclasses.field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, dead_timeout_s: float = 300.0,
                 straggler_sigma: float = 3.0, window: int = 32):
        self.dead_timeout = dead_timeout_s
        self.sigma = straggler_sigma
        self.window = window
        self.hosts: Dict[int, HostStatus] = {
            h: HostStatus(time.time(), -1) for h in range(n_hosts)}

    def beat(self, host: int, step: int, step_time_s: float,
             now: Optional[float] = None):
        st = self.hosts[host]
        st.last_beat = now if now is not None else time.time()
        st.last_step = step
        st.step_times.append(step_time_s)
        del st.step_times[:-self.window]

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.dead_timeout]

    def stragglers(self) -> List[int]:
        """Median-based outlier rule: a mean/stddev threshold is corrupted
        by the straggler itself on small fleets (one 5x host in 4 shifts
        mu+3sigma past it); the median is robust to <50% stragglers."""
        means = {h: (sum(st.step_times) / len(st.step_times))
                 for h, st in self.hosts.items() if st.step_times}
        if len(means) < 2:
            return []
        vals = sorted(means.values())
        med = vals[len(vals) // 2]
        return [h for h, v in means.items() if v > self.sigma * med]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Pure (world_size -> shard map) replan; pairs with the deterministic
    pipeline so resizing never duplicates or drops data."""
    global_batch: int
    n_hosts: int

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError(
                f"global_batch {self.global_batch} must divide over "
                f"{self.n_hosts} hosts")

    def shard_for(self, host: int):
        per = self.global_batch // self.n_hosts
        return slice(host * per, (host + 1) * per)

    def resize(self, n_hosts: int) -> "ElasticPlan":
        return ElasticPlan(self.global_batch, n_hosts)


class StragglerMitigator:
    """Deadline policy: if a host misses the step deadline, the step result
    is taken without it (backup-worker semantics) and the host replays the
    deterministic batch on rejoin."""

    def __init__(self, deadline_factor: float = 3.0):
        self.deadline_factor = deadline_factor
        self._median: Optional[float] = None

    def observe(self, step_time_s: float):
        self._median = (step_time_s if self._median is None
                        else 0.9 * self._median + 0.1 * step_time_s)

    def deadline(self) -> Optional[float]:
        return None if self._median is None else \
            self.deadline_factor * self._median

    def should_drop(self, elapsed_s: float) -> bool:
        d = self.deadline()
        return d is not None and elapsed_s > d


class CrashBarrier:
    """Inject faults at chosen steps; the Trainer catches ``SimulatedFault``
    and exercises its restore path (tests assert bit-exact resumption)."""

    class SimulatedFault(RuntimeError):
        pass

    def __init__(self, crash_at_steps=()):
        self.crash_at = set(crash_at_steps)
        self.fired = set()

    def check(self, step: int):
        if step in self.crash_at and step not in self.fired:
            self.fired.add(step)
            raise self.SimulatedFault(f"injected fault at step {step}")
