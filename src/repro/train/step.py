"""Train-step factory: loss -> grads -> (optional posit wire compression)
-> AdamW -> new state.  TC-aware: the TCPolicy enters the forward through
``loss_fn`` (fake-quant on weights per role/layer/node) and, when
``policy.grad_wire`` is set, the data-parallel gradient payload is posit-
compressed with error feedback before the (XLA-inserted) all-reduce.

The returned step is a pure function suitable for ``jax.jit`` with explicit
in/out shardings — the launcher and the multi-pod dry-run both consume it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.transprecision import BF16, TCPolicy
from ..models import lm
from ..optim import adamw_init, adamw_update, AdamWConfig
from ..optim.compression import error_feedback_update


@dataclasses.dataclass
class TrainState:
    """Pytree of everything a restart needs (params live separately)."""
    params: Any
    opt: Any
    ef_residual: Optional[Any] = None   # error-feedback state (grad_wire)

    def tree_flatten(self):
        return (self.params, self.opt, self.ef_residual), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def init_train_state(key, cfg: lm.ModelCfg, opt_cfg: AdamWConfig,
                     policy: TCPolicy = BF16, abstract: bool = False):
    def build(key):
        params = lm.init_params(key, cfg)
        opt = adamw_init(params)
        ef = None
        if policy.grad_wire:
            ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return TrainState(params, opt, ef)
    if abstract:
        return jax.eval_shape(build, key)
    return build(key)


def make_train_step(cfg: lm.ModelCfg, opt_cfg: AdamWConfig,
                    policy: TCPolicy = BF16):
    """Returns step(state, batch) -> (state, metrics)."""

    def step(state: TrainState, batch: Dict[str, jax.Array]):
        def loss(p):
            return lm.loss_fn(p, batch, cfg, policy)

        (loss_val, parts), grads = jax.value_and_grad(loss, has_aux=True)(
            state.params)

        ef = state.ef_residual
        if policy.grad_wire:
            grads, ef = error_feedback_update(grads, ef, policy.grad_wire)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg)
        metrics = {"loss": loss_val, **parts, **opt_metrics}
        return TrainState(new_params, new_opt, ef), metrics

    return step


def state_specs(cfg: lm.ModelCfg, pspecs, policy: TCPolicy = BF16):
    """TrainState PartitionSpecs mirroring param specs (FSDP-consistent)."""
    from ..launch.mesh import opt_specs
    from jax.sharding import PartitionSpec as P
    opt = opt_specs(pspecs)
    ef = pspecs if policy.grad_wire else None
    return TrainState(pspecs, opt, ef)
