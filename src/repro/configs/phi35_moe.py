"""phi3.5-moe-42b-a6.6b [moe] — hf:microsoft/Phi-3.5-MoE-instruct.

32L d_model=4096 32H (GQA kv=8) d_ff=6400, MoE 16 experts top-2,
vocab=32064.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="phi3.5-moe-42b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab=32064, mlp="swiglu",
        moe_experts=16, moe_topk=2, capacity_factor=1.25,
        rope_theta=10000.0,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="phi35-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, mlp="swiglu",
        moe_experts=4, moe_topk=2, capacity_factor=1.25,
    )
