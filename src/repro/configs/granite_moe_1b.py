"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.

24L d_model=1024 16H (GQA kv=8) d_ff=512, MoE 32 experts top-8,
vocab=49155; tied embeddings.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="granite-moe-1b", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab=49155, mlp="swiglu",
        moe_experts=32, moe_topk=8, capacity_factor=1.25,
        tie_embed=True,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=128, mlp="swiglu",
        moe_experts=8, moe_topk=4, capacity_factor=1.25, tie_embed=True,
    )
