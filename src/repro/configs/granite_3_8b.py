"""granite-3-8b [dense] — GQA, hf:ibm-granite/granite-3.0 family.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155; tied embeddings.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab=49155, mlp="swiglu",
        rope_theta=10000.0, tie_embed=True,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="granite-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=131, mlp="swiglu", tie_embed=True,
    )
