"""Assigned-architecture registry: ``--arch <id>`` resolution.

Each module defines ``full()`` (the exact published config) and ``smoke()``
(a reduced same-family config for CPU tests).  ``SHAPES`` carries the four
assigned input shapes; ``cells(arch)`` yields the (arch x shape) dry-run
cells with the sub-quadratic skip rule applied (long_500k only runs for
recurrent-state families — see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "llama3-8b": "llama3_8b",
    "granite-3-8b": "granite_3_8b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "whisper-large-v3": "whisper_large_v3",
    # the paper's own deployment target (not part of the 40 assigned cells)
    "paper-edge": "paper_edge",
}

ARCHS = tuple(k for k in _MODULES if k != "paper-edge")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# families with O(1)-state decode can run the 500k cell
SUBQUADRATIC = ("ssm", "hybrid")


def get_module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str, smoke: bool = False):
    mod = get_module(arch)
    return mod.smoke() if smoke else mod.full()


def shape_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skip) for an (arch x shape) cell."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    if spec.name == "long_500k" and cfg.family not in SUBQUADRATIC:
        return False, ("full-attention family: 500k-token KV decode is "
                       "quadratic-cost/O(seq) memory; skipped per assignment "
                       "(DESIGN.md §Arch-applicability)")
    return True, ""


def cells():
    """All 40 assigned (arch, shape) cells, with skip annotations."""
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = shape_applicable(arch, shape)
            yield arch, shape, ok, why
