"""paper-edge — the paper's own deployment point: a small edge LM running
with the P(8,2) transprecision policy ("Posit P(8,2) is exclusively used
for vector operations, as this configuration is most used for DNNs
deployed on edge devices", §IV-D).

Used by the examples and the end-to-end driver; not part of the 40
assigned dry-run cells.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    # ~100M params: the end-to-end training deliverable size
    return ModelCfg(
        name="paper-edge-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab=32000, mlp="swiglu",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="paper-edge-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, mlp="swiglu",
    )
