"""qwen3-4b [dense] — qk_norm + GQA, hf:Qwen/Qwen3 family.

36L d_model=2560 32H (GQA kv=8, head_dim=128) d_ff=9728 vocab=151936.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=9728, vocab=151936, mlp="swiglu",
        rope_theta=1000000.0, qk_norm=True,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=128, vocab=160, mlp="swiglu", qk_norm=True,
    )
