"""qwen2-vl-2b [vlm] — M-RoPE + dynamic resolution, arXiv:2409.12191.

28L d_model=1536 12H (GQA kv=2, head_dim=128) d_ff=8960 vocab=151936.
The vision frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed patch embeddings (B, S, d_model); the backbone (incl. the
M-RoPE section split) is real.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
        d_ff=8960, vocab=151936, mlp="swiglu",
        rope_theta=1000000.0, mrope=True, tie_embed=True,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="qwen2vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=128, vocab=128, mlp="swiglu", mrope=True, tie_embed=True,
    )
