"""mamba2-2.7b [ssm] — SSD (state-space duality), arXiv:2405.21060.

64L d_model=2560, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, headdim 64 -> 80 SSD heads, 1 B/C group.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, d_ff=0, vocab=50280,
        n_heads=1, n_kv_heads=1,           # unused (attn-free)
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ssm_groups=1, conv_kernel=4,
        tie_embed=True,                    # mamba2 ties lm_head to embedding
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, d_ff=0, vocab=128,
        n_heads=1, n_kv_heads=1,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=32,
        ssm_groups=1, conv_kernel=4, tie_embed=True,
    )
