"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2, arXiv:2402.19427.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000; pattern is
(rec, rec, local-attn) with a 2048-token sliding window (Griffin).
38 = 12 periods x 3 + 2 tail recurrent blocks.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab=256000, mlp="gelu",
        pattern=("rec", "rec", "attn"), window=2048,
        conv_kernel=4, tie_embed=True,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="rgemma-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=128, mlp="gelu",
        pattern=("rec", "rec", "attn"), window=16,
        conv_kernel=4, tie_embed=True,
    )
