"""whisper-large-v3 [audio] — enc-dec, arXiv:2212.04356.

32L (decoder) d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866;
32-layer encoder over 1500 mel frames.  The conv frontend is a STUB per
the assignment: ``input_specs()`` provides precomputed frame embeddings
(B, 1500, d_model).  Decode shapes use the decoder self-KV of seq_len plus
the fixed 1500-frame cross-attention memory.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab=51866, mlp="gelu",
        enc_layers=32, enc_seq=1500,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, mlp="gelu",
        enc_layers=2, enc_seq=24,
    )
