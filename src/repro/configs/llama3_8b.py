"""llama3-8b [dense] — GQA, 128k vocab, arXiv:2407.21783.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, rope 500k.
"""
from ..models.lm import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, mlp="swiglu",
        rope_theta=500000.0,
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="llama3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, mlp="swiglu", rope_theta=500000.0,
    )
