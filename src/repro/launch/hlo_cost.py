"""Loop-aware cost analysis of a compiled (post-SPMD, per-device) HLO module.

``compiled.cost_analysis()`` on the CPU backend counts a ``while`` body
ONCE, regardless of trip count (verified: scan(n=4) and scan(n=8) report
identical FLOPs).  Our production programs are scan-over-layers + flash
attention loops, so naive cost_analysis under-reports by ~n_layers x
n_blocks.  This module re-derives FLOPs / HBM bytes / collective bytes from
``compiled.as_text()`` with call-graph multiplicity:

  * ``while``       — body and condition costs x ``known_trip_count`` (from
                      backend_config, emitted by XLA on every scan/fori).
  * ``fusion``      — FLOPs of the fused computation counted once; HBM bytes
                      taken at the call site (operands + result), matching
                      the fusion-aware accounting of HloCostAnalysis: fused
                      intermediates never touch HBM.
  * ``call``/others — multiplicity 1.
  * collectives     — result-shape bytes (for all-gather this is the
                      gathered payload each device receives ~= wire bytes;
                      for all-reduce/all-to-all/collective-permute result ==
                      operand payload), times loop multiplicity.

FLOP counting: ``dot`` = 2 * prod(result dims) * prod(contracting dims);
``convolution`` = 2 * prod(result) * prod(kernel spatial+input-feature);
elementwise/reduce ~= 1 FLOP per output (transcendentals ~= 1 — they are
noise next to the dots at these shapes).

This is the source for EXPERIMENTS.md §Roofline; tests cross-check it
against ``cost_analysis()`` on loop-free programs (where both are exact)
and against scan-vs-unrolled equivalence.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5, "s8": 1,
    "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that are pure data movement / bookkeeping: 0 FLOPs
_ZERO_FLOP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "copy-start", "copy-done", "reshape",
    "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "reverse", "gather",
    "scatter", "iota", "convert", "reduce-precision", "after-all",
    "partition-id", "replica-id", "rng", "rng-bit-generator", "infeed",
    "outfeed", "optimization-barrier", "custom-call", "send", "recv",
    "send-done", "recv-done", "domain", "select", "clamp", "sort",
} | set(COLLECTIVES) | {c + s for c in COLLECTIVES for s in
                        ("-start", "-done")}


def _shape_dims(txt: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(txt: str) -> float:
    total = 0.0
    for dt, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _bytes_map(txt: str) -> Dict[str, float]:
    """Like :func:`_bytes_of` but split by dtype — the basis of the
    per-dtype HBM attribution the energy model consumes (posit-packed KV
    code buffers show up as ``u8``/``u16``, their scales as ``f32``)."""
    out: Dict[str, float] = {}
    for dt, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        out[dt] = out.get(dt, 0.0) + n * _DTYPE_BYTES[dt]
    return out


def _scale_map(bmap: Dict[str, float], k: float) -> Dict[str, float]:
    return {dt: v * k for dt, v in bmap.items()}


def _merge_maps(*maps: Dict[str, float]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for m in maps:
        for dt, v in m.items():
            out[dt] = out.get(dt, 0.0) + v
    return out


def _first_dtype(txt: str, default: str = "f32") -> str:
    for m in _SHAPE_RE.finditer(txt):
        if m.group(1) in _DTYPE_BYTES:
            return m.group(1)
    return default


def _elems_of(txt: str) -> float:
    total = 0.0
    for _, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result: str          # result type text (before op name)
    op: str
    args: str            # inside parens
    attrs: str           # after parens


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]

    def table(self) -> Dict[str, str]:
        """instr name -> result type text (operands are printed untyped)."""
        return {i.name: i.result for i in self.instrs}


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},\d]+)+)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4), m.group(5)))
    return comps, entry


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(rf"{key}=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(attrs: str) -> Optional[int]:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else None


_ARG_NAME_RE = re.compile(r"%([\w.\-]+)")


def _arg_types(instr: Instr, table: Dict[str, str]) -> List[str]:
    """Resolve operand names to their result-type text."""
    out = []
    for m in _ARG_NAME_RE.finditer(instr.args):
        t = table.get(m.group(1))
        if t is not None:
            out.append(t)
    # inline-typed operands (older dumps) appear directly in args
    if not out and _SHAPE_RE.search(instr.args):
        out = [instr.args]
    return out


def _dot_flops(instr: Instr, table: Dict[str, str]) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    res = _shape_dims(instr.result)
    if not res:
        return 0.0
    result_elems = 1
    for d in res[0][1]:
        result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    ops = _arg_types(instr, table)
    if not m or not ops:
        return 2.0 * result_elems            # fallback
    lhs = _shape_dims(ops[0])
    if not lhs:
        return 2.0 * result_elems
    lhs_dims = lhs[0][1]
    contract = 1
    for i in m.group(1).split(","):
        if i != "":
            contract *= lhs_dims[int(i)]
    return 2.0 * result_elems * contract


def _conv_flops(instr: Instr, table: Dict[str, str]) -> float:
    res = _shape_dims(instr.result)
    ops = [_shape_dims(t) for t in _arg_types(instr, table)]
    ops = [o for o in ops if o]
    if not res or len(ops) < 2:
        return 0.0
    result_elems = 1
    for d in res[0][1]:
        result_elems *= d
    kernel_elems = 1
    for d in ops[1][0][1]:
        kernel_elems *= d
    # per output element: 2 * kernel_elems / output_features (approx)
    out_feat = res[0][1][-1] if res[0][1] else 1
    return 2.0 * result_elems * kernel_elems / max(out_feat, 1)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    # the dot/convolution share of ``flops``: the program's actual MAC
    # work.  Elementwise flops (softmax, norms — and crucially the
    # in-graph fake-quant decode of posit/bf16-packed weights, which a
    # transprecision ALU performs natively inside the MAC datapath) are
    # counted in ``flops`` but not here, so the serving energy model can
    # price real MACs without charging for the QAT emulation.
    mac_flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0.0, "bytes": 0.0}
                                 for k in COLLECTIVES})
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    # per-dtype splits of the two totals above (flops keyed by result
    # dtype, bytes by the dtype of each buffer touched) — the inputs the
    # serving energy model (repro.obs.energy) attributes to MAC formats
    # and DRAM traffic.  Invariant: each sums to its total exactly.
    flops_by_dtype: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    bytes_by_dtype: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.mac_flops += mult * other.mac_flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVES:
            self.coll[k]["count"] += mult * other.coll[k]["count"]
            self.coll[k]["bytes"] += mult * other.coll[k]["bytes"]
        for k, v in other.by_op.items():
            self.by_op[k] = self.by_op.get(k, 0.0) + mult * v
        for k, v in other.flops_by_dtype.items():
            self.flops_by_dtype[k] = (self.flops_by_dtype.get(k, 0.0)
                                      + mult * v)
        for k, v in other.bytes_by_dtype.items():
            self.bytes_by_dtype[k] = (self.bytes_by_dtype.get(k, 0.0)
                                      + mult * v)

    def _op_bytes(self, op: str, bmap: Dict[str, float]):
        b = sum(bmap.values())
        self.bytes += b
        self.by_op[op] = self.by_op.get(op, 0.0) + b
        for dt, v in bmap.items():
            self.bytes_by_dtype[dt] = self.bytes_by_dtype.get(dt, 0.0) + v

    def _add_flops(self, n: float, dtype: str, mac: bool = False):
        self.flops += n
        if mac:
            self.mac_flops += n
        if n:
            self.flops_by_dtype[dtype] = (self.flops_by_dtype.get(dtype, 0.0)
                                          + n)

    @property
    def coll_bytes(self) -> float:
        return sum(v["bytes"] for v in self.coll.values())


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def cost(self) -> Cost:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        return self._comp_cost(self.entry, top=True)

    # ---- internals ----
    _SLICED = ("slice", "dynamic-slice", "gather")

    def _fusion_param_bytes(self, callee: str,
                            arg_types: List[str]) -> Dict[str, float]:
        """Bytes a fusion actually reads from each operand, split by dtype:
        a parameter whose only uses inside the fused computation are
        slice/dynamic-slice/gather contributes the sliced bytes, not the
        whole array (this is how scan bodies read one layer's weights from
        the stacked (L, ...) buffers — charging the full stack per trip
        would overcount HBM traffic ~L x)."""
        key = ("__fb__", callee)
        comp = self.comps.get(callee)
        if comp is None:
            return _merge_maps(*[_bytes_map(t) for t in arg_types])
        if key not in self._memo:
            params: Dict[str, int] = {}
            for ins in comp.instrs:
                if ins.op == "parameter":
                    try:
                        params[ins.name] = int(ins.args.strip().strip("%"))
                    except ValueError:
                        pass
            # per param: None = fully read; float = sliced bytes
            access: Dict[int, Optional[float]] = {}
            for pname, idx in params.items():
                sliced = 0.0
                full = False
                used = False
                for ins in comp.instrs:
                    if ins.op == "parameter":
                        continue
                    names = [m.group(1) for m in
                             _ARG_NAME_RE.finditer(ins.args)]
                    if pname not in names:
                        continue
                    used = True
                    if ins.op in self._SLICED:
                        sliced += _bytes_of(ins.result)
                    else:
                        full = True
                        break
                access[idx] = None if (full or not used) else sliced
            self._memo[key] = access          # type: ignore
        access = self._memo[key]              # type: ignore
        total: Dict[str, float] = {}
        for i, t in enumerate(arg_types):
            a = access.get(i)
            full_b = _bytes_of(t)
            if a is None or a >= full_b:
                part = _bytes_map(t)
            else:
                # sliced reads keep the parameter's dtype (a slice of the
                # u8 code pool is still u8 traffic)
                part = {_first_dtype(t): a}
            total = _merge_maps(total, part)
        return total

    def _comp_cost(self, name: str, top: bool) -> Cost:
        key = (name, top)
        if key in self._memo:
            return self._memo[key]
        c = Cost()
        comp = self.comps.get(name)
        if comp is None:
            self._memo[key] = c
            return c
        table = comp.table()
        for ins in comp.instrs:
            self._instr_cost(ins, c, top, table)
        self._memo[key] = c
        return c

    def _instr_cost(self, ins: Instr, c: Cost, top: bool,
                    table: Dict[str, str]):
        op = ins.op
        # --- control flow / calls ---
        if op == "while":
            body = _called(ins.attrs, "body")
            cond = _called(ins.attrs, "condition")
            trips = _trip_count(ins.attrs) or 1
            if body:
                c.add(self._comp_cost(body, top), trips)
            if cond:
                c.add(self._comp_cost(cond, top), trips + 1)
            return
        if op == "conditional":
            m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
            if m:
                branches = [b.strip().lstrip("%") for b in
                            m.group(1).split(",")]
                costs = [self._comp_cost(b, top) for b in branches]
                if costs:   # worst case branch
                    c.add(max(costs, key=lambda x: x.flops))
            return
        arg_types = _arg_types(ins, table)
        arg_bytes = sum(_bytes_of(t) for t in arg_types)
        arg_bmap = _merge_maps(*[_bytes_map(t) for t in arg_types])
        # sliced reads/writes only touch the slice, not the whole operand
        if op in ("slice", "dynamic-slice", "gather"):
            c._op_bytes(op, _scale_map(_bytes_map(ins.result), 2))
            return
        if op == "dynamic-update-slice":
            upd = arg_types[1] if len(arg_types) > 1 else ins.result
            c._op_bytes(op, _scale_map(_bytes_map(upd), 2))
            return
        if op == "scatter":
            if arg_types:
                c._add_flops(_elems_of(arg_types[-1]),
                             _first_dtype(arg_types[-1]))
                upd = arg_types[-1]
            else:
                upd = ins.result
            c._op_bytes(op, _scale_map(_bytes_map(upd), 2))
            return
        if op == "fusion":
            callee = _called(ins.attrs, "calls")
            fusion_bmap = _merge_maps(_bytes_map(ins.result), arg_bmap)
            if callee:
                inner = self._comp_cost(callee, top=False)
                c._add_flops(inner.flops, _first_dtype(ins.result))
                c.mac_flops += inner.mac_flops
                for k in COLLECTIVES:
                    c.coll[k]["count"] += inner.coll[k]["count"]
                    c.coll[k]["bytes"] += inner.coll[k]["bytes"]
                fusion_bmap = _merge_maps(
                    _bytes_map(ins.result),
                    self._fusion_param_bytes(callee, arg_types))
            # HBM traffic at the fusion boundary, utilization-aware
            c._op_bytes(op, fusion_bmap)
            return
        if op == "call":
            callee = _called(ins.attrs, "to_apply")
            if callee:
                c.add(self._comp_cost(callee, top))
            return

        # --- collectives (incl. async -start forms) ---
        for k in COLLECTIVES:
            if op == k or op == k + "-start":
                c.coll[k]["count"] += 1
                # result bytes: for -start ops the result is a tuple
                # (operand, result[, scratch]); take the non-operand part
                rb = _bytes_of(ins.result)
                if op.endswith("-start") and rb >= arg_bytes > 0:
                    rb = rb - arg_bytes
                c.coll[k]["bytes"] += rb
                c._op_bytes(op, _merge_maps(
                    arg_bmap, {_first_dtype(ins.result): rb}))
                return
            if op == k + "-done":
                return

        # --- compute ---
        if op == "dot":
            c._add_flops(_dot_flops(ins, table), _first_dtype(ins.result),
                         mac=True)
            c._op_bytes(op, _merge_maps(_bytes_map(ins.result), arg_bmap))
            return
        if op == "convolution":
            c._add_flops(_conv_flops(ins, table), _first_dtype(ins.result),
                         mac=True)
            c._op_bytes(op, _merge_maps(_bytes_map(ins.result), arg_bmap))
            return
        if op in ("reduce", "reduce-window", "map", "scatter",
                  "select-and-scatter"):
            args = _arg_types(ins, table)
            if args:
                c._add_flops(_elems_of(args[0]), _first_dtype(args[0]))
            else:
                c._add_flops(_elems_of(ins.result),
                             _first_dtype(ins.result))
            c._op_bytes(op, _merge_maps(_bytes_map(ins.result), arg_bmap))
            return
        if op in _ZERO_FLOP:
            if op not in ("parameter", "constant", "tuple",
                          "get-tuple-element", "iota", "after-all",
                          "bitcast", "bitcast-convert"):
                c._op_bytes(op, _merge_maps(_bytes_map(ins.result),
                                            arg_bmap))
            return
        # generic elementwise (add/multiply/exp/...)
        c._add_flops(_elems_of(ins.result), _first_dtype(ins.result))
        c._op_bytes(op, _merge_maps(_bytes_map(ins.result), arg_bmap))


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a list of per-module dicts (one per partition /
    executable module); newer JAX returns the entry module's dict
    directly.  Returns one flat dict, summing numeric keys across modules
    so loop-free single-module programs are unchanged either way.
    """
    if isinstance(ca, dict):
        return ca
    out: Dict[str, float] = {}
    for mod in ca:
        for k, v in mod.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
            else:
                out.setdefault(k, v)
    return out


def analyze(hlo_text: str) -> Dict[str, object]:
    cost = HloCostModel(hlo_text).cost()
    return {
        "flops": cost.flops,
        "mac_flops": cost.mac_flops,
        "bytes": cost.bytes,
        "flops_by_dtype": dict(cost.flops_by_dtype),
        "bytes_by_dtype": dict(cost.bytes_by_dtype),
        "collective_bytes": cost.coll_bytes,
        "collectives": {k: dict(v) for k, v in cost.coll.items()},
    }


def entry_param_bytes_by_dtype(hlo_text: str) -> Dict[str, float]:
    """Bytes of the ENTRY computation's parameters, split by dtype.

    For a decode-stage program the entry parameters are exactly (params,
    decode state), so the posit-packed KV code buffers show up here as
    the program's ``u8``/``u16`` share — the cross-check that the energy
    model's KV-traffic attribution matches the engine's
    ``kv_cache_bytes`` accounting (``tests/test_energy.py``)."""
    comps, entry = parse_module(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    out: Dict[str, float] = {}
    for ins in comps[entry].instrs:
        if ins.op == "parameter":
            for dt, v in _bytes_map(ins.result).items():
                out[dt] = out.get(dt, 0.0) + v
    return out
