"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs the Trainer on whatever devices exist (CPU here, a TPU slice in
production — the same code path: mesh + rules + jitted step).  Smoke-scale
by default; ``--full`` selects the assigned full config (only sensible on
real hardware).
"""
from __future__ import annotations

import argparse

from ..core.transprecision import PRESETS
from ..configs import get_config
from ..optim import AdamWConfig
from ..train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-edge")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", default="bf16", choices=sorted(PRESETS))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    tcfg = TrainerConfig(steps=args.steps, global_batch=args.batch,
                         seq_len=args.seq, checkpoint_dir=args.ckpt_dir,
                         checkpoint_every=args.ckpt_every)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 10))
    trainer = Trainer(cfg, tcfg, opt, policy=args.policy)
    out = trainer.run()
    print("final:", out["metrics"])


if __name__ == "__main__":
    main()
