import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell against
# the production mesh, prove it fits, and extract the roofline terms.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
#       --shape train_4k --mesh pod1 --out benchmarks/results/dryrun
#
# The XLA_FLAGS line above MUST precede any jax import: jax locks the device
# count on first init.  512 placeholder host devices back both the single-pod
# (16,16) and multi-pod (2,16,16) meshes.
# ---------------------------------------------------------------------------
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config, shape_applicable
from ..core.transprecision import get_policy
from ..models.common import axis_rules
from ..models.lm import ModelCfg
from ..models.serve_model import decode_step, prefill
from ..optim import AdamWConfig
from ..train.step import init_train_state, make_train_step, state_specs
from . import hlo_cost
from . import mesh as mesh_lib
from .specs import decode_specs, input_specs

# v5e-class hardware constants (per chip) for the roofline terms
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
HBM_CAP = 16e9               # bytes


# ---------------------------------------------------------------------------
# Collective parsing from post-SPMD HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(txt: str) -> int:
    """Sum bytes over every typed shape literal in ``txt``."""
    total = 0.0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return int(total)


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-collective-op byte accounting from the per-device HLO module.

    For each op we take the *result* shape bytes (for all-gather this is the
    gathered tensor ~= wire bytes in+out per device; for all-reduce /
    reduce-scatter / all-to-all / collective-permute the operand and result
    describe the same payload).  ``operand_bytes`` (the spec's "sum of
    operand sizes") is also recorded from the inline-typed operands.
    """
    per_kind = {k: {"count": 0, "result_bytes": 0, "operand_bytes": 0}
                for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*)", ls)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLL_KINDS:
            if re.search(rf"\b{k}(?:-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:      # avoid double counting async pairs
            continue
        lhs, _, call = rhs.partition(f" {kind}")
        per_kind[kind]["count"] += 1
        per_kind[kind]["result_bytes"] += _shape_bytes(lhs)
        inner = call[call.find("(") + 1: call.rfind(")")] if call else ""
        per_kind[kind]["operand_bytes"] += _shape_bytes(inner)
    total_result = sum(v["result_bytes"] for v in per_kind.values())
    total_operand = sum(v["operand_bytes"] for v in per_kind.values())
    return {"per_kind": per_kind, "result_bytes": total_result,
            "operand_bytes": total_operand}


def hlo_op_histogram(hlo_text: str, top: int = 12) -> Dict[str, int]:
    ops: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT )?%?[\w.\-]+ = \S+ ([\w\-]+)\(", line)
        if m:
            ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    return dict(sorted(ops.items(), key=lambda kv: -kv[1])[:top])


# ---------------------------------------------------------------------------
# Model-FLOPs accounting (6ND / 2ND with MoE active-param scaling)
# ---------------------------------------------------------------------------

def active_params(cfg: ModelCfg) -> Dict[str, float]:
    from ..models.lm import init_params
    p = init_params(jax.random.PRNGKey(0), cfg, abstract=True)
    total = active = 0.0
    for kp, leaf in jax.tree_util.tree_flatten_with_path(p)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        n = float(np.prod(leaf.shape))
        total += n
        if "moe" in path and path.split("/")[-1] in ("wi", "wo"):
            active += n * cfg.moe_topk / cfg.moe_experts
        else:
            active += n
    return {"total": total, "active": active}


def model_flops(cfg: ModelCfg, kind: str, batch: int, seq: int,
                n_active: float) -> float:
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Variant:
    """Hillclimb knobs (defaults = baseline).

    The baseline is the production sharding: FSDP(data) x TP(model) with
    sequence-parallel residuals and head-sharded attention — the weakest
    configs that still FIT 16 GB/chip (seq/heads sharding off blows HBM at
    train_4k; see EXPERIMENTS.md §Dry-run)."""
    policy: str = "bf16"
    seq_shard: bool = True          # sequence-parallel residual stream
    heads_shard: bool = True        # shard attention heads on "model"
    remat: Optional[str] = None     # override cfg.remat
    scan_layers: Optional[bool] = None
    distributed_decode: bool = False  # shard_map LSE decode attention
    q_block: Optional[int] = None
    kv_block: Optional[int] = None
    attn_vjp: Optional[str] = None    # flash | naive
    packed: bool = False              # posit-packed weights/KV (serving)

    def apply(self, cfg: ModelCfg) -> ModelCfg:
        kw = {}
        if self.remat is not None:
            kw["remat"] = self.remat
        if self.scan_layers is not None:
            kw["scan_layers"] = self.scan_layers
        if self.q_block:
            kw["q_block"] = self.q_block
        if self.kv_block:
            kw["kv_block"] = self.kv_block
        if self.attn_vjp:
            kw["attn_vjp"] = self.attn_vjp
        return dataclasses.replace(cfg, **kw) if kw else cfg


def lower_cell(arch: str, shape: str, multi_pod: bool,
               variant: Variant = Variant()):
    """Lower + compile one (arch x shape x mesh) cell; return report dict."""
    cfg = variant.apply(get_config(arch))
    spec = SHAPES[shape]
    policy = get_policy(variant.policy)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    if spec.kind == "train":
        rules = mesh_lib.train_rules(mesh, global_batch=spec.global_batch,
                                     seq_shard=variant.seq_shard,
                                     heads_shard=variant.heads_shard)
    else:
        rules = mesh_lib.serve_rules(mesh, global_batch=spec.global_batch)

    opt_cfg = AdamWConfig()
    with mesh, axis_rules(rules):
        abstract_params = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                     policy).params)
        if variant.packed and spec.kind != "train":
            # posit-packed serving weights (decode-on-load)
            from ..core.transprecision import pack_params
            abstract_params = pack_params(abstract_params, policy,
                                          abstract=True)
        fsdp = "data" if spec.kind == "train" else None
        pspecs = mesh_lib.param_specs(abstract_params, fsdp=fsdp)
        psh = mesh_lib.to_shardings(mesh, pspecs)

        if spec.kind == "train":
            state_abs = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                         policy, abstract=True)
            ssh = mesh_lib.to_shardings(
                mesh, state_specs(cfg, pspecs, policy))
            bsh = mesh_lib.to_shardings(
                mesh, mesh_lib.batch_specs(cfg, rules))
            step = make_train_step(cfg, opt_cfg, policy)
            jitted = jax.jit(step, in_shardings=(ssh, bsh),
                             out_shardings=(ssh, None), donate_argnums=0)
            lowered = jitted.lower(state_abs, input_specs(cfg, spec))
        elif spec.kind == "prefill":
            batch = input_specs(cfg, spec)
            bsh = mesh_lib.to_shardings(
                mesh, mesh_lib.batch_specs(cfg, rules, keys=set(batch)))

            def prefill_fn(params, b):
                return prefill(params, b, cfg, spec.seq_len, policy)

            jitted = jax.jit(prefill_fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(abstract_params, batch)
        else:  # decode
            cache_abs, tok = decode_specs(cfg, spec, policy)
            csh = mesh_lib.to_shardings(
                mesh, mesh_lib.cache_specs(cache_abs, cfg, rules))
            tok_sh = mesh_lib.to_shardings(
                mesh, jax.sharding.PartitionSpec(rules.get("batch"), None)
                if cfg.family != "vlm" else
                jax.sharding.PartitionSpec(rules.get("batch"), None, None))
            if variant.distributed_decode:
                from ..serve.distributed import make_distributed_decode_step
                step = make_distributed_decode_step(cfg, policy, mesh, rules)
            else:
                def step(params, cache, tok):
                    if cfg.family == "vlm":
                        return decode_step(params, cache, None, cfg, policy,
                                           embeds=tok)
                    return decode_step(params, cache, tok, cfg, policy)
            jitted = jax.jit(step, in_shardings=(psh, csh, tok_sh),
                             out_shardings=None, donate_argnums=1)
            lowered = jitted.lower(abstract_params, cache_abs, tok)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- extract analysis ----
    # NOTE: compiled.cost_analysis() counts while-loop bodies ONCE on this
    # backend (verified: scan(4) == scan(8)); the production programs are
    # scan-over-layers, so the roofline terms come from the loop-aware HLO
    # parser (hlo_cost.analyze — trip counts from known_trip_count), which
    # matches cost_analysis exactly on loop-free modules (tested).
    xla_cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_d: Dict[str, Any] = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_d[f] = int(v)
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)
    coll = parse_collectives(hlo)   # single-instance per-kind cross-check
    np_info = active_params(cfg)
    flops = float(cost["flops"])
    bytes_acc = float(cost["bytes"])
    coll_bytes = float(cost["collective_bytes"])

    # roofline terms (per-device quantities vs per-chip peaks)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_bytes / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, spec.kind, spec.global_batch, spec.seq_len,
                     np_info["active"])
    mf_per_dev = mf / n_chips

    report = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "kind": spec.kind,
        "variant": dataclasses.asdict(variant),
        "params_total": np_info["total"], "params_active": np_info["active"],
        "xla_cost_analysis_loopbody_once": {
            k: float(v) for k, v in xla_cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
        },
        "hlo_cost": {"flops": flops, "bytes": bytes_acc,
                     "collectives": cost["collectives"]},
        "memory_analysis": mem_d,
        "collectives_single_instance": coll,
        "hlo_ops": hlo_op_histogram(hlo),
        "roofline": {
            "flops_per_device": flops,
            "hbm_bytes_per_device": bytes_acc,
            "collective_bytes_per_device": coll_bytes,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops_global": mf,
            "model_flops_per_device": mf_per_dev,
            "useful_flops_ratio": (mf_per_dev / flops) if flops else 0.0,
            "roofline_fraction": (mf_per_dev / PEAK_FLOPS)
            / max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0 else 0.0,
        },
        "fits_hbm": mem_d.get("temp_size_in_bytes", 0)
        + mem_d.get("argument_size_in_bytes", 0) <= HBM_CAP,
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
    }
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-heads-shard", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--no-scan", action="store_true")
    ap.add_argument("--distributed-decode", action="store_true")
    ap.add_argument("--q-block", type=int, default=0)
    ap.add_argument("--kv-block", type=int, default=0)
    ap.add_argument("--attn-vjp", default=None, choices=["flash", "naive"])
    ap.add_argument("--packed", action="store_true",
                    help="posit-packed weights/KV for serve cells")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    ok, why = shape_applicable(args.arch, args.shape)
    name = f"{args.arch}_{args.shape}_{args.mesh}" + (
        f"_{args.tag}" if args.tag else "")
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, name + ".json")
    if not ok:
        json.dump({"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                   "skipped": True, "reason": why}, open(path, "w"), indent=1)
        print(f"SKIP {name}: {why}")
        return

    variant = Variant(
        policy=args.policy, seq_shard=not args.no_seq_shard,
        heads_shard=not args.no_heads_shard, remat=args.remat,
        scan_layers=False if args.no_scan else None,
        distributed_decode=args.distributed_decode,
        q_block=args.q_block, kv_block=args.kv_block,
        attn_vjp=args.attn_vjp, packed=args.packed)
    report = lower_cell(args.arch, args.shape, args.mesh == "pod2", variant)
    report["tag"] = args.tag
    json.dump(report, open(path, "w"), indent=1)
    r = report["roofline"]
    print(f"OK {name}: dominant={r['dominant']} "
          f"compute={r['t_compute_s']:.4f}s memory={r['t_memory_s']:.4f}s "
          f"collective={r['t_collective_s']:.4f}s "
          f"frac={r['roofline_fraction']:.3f} "
          f"mem={report['memory_analysis']} "
          f"compile={report['timings']['compile_s']:.0f}s")


if __name__ == "__main__":
    main()
