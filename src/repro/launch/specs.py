"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

``input_specs(cfg, shape)`` -> dict of ShapeDtypeStructs for train/prefill;
``decode_specs`` additionally builds the abstract KV/state cache pre-sized to
``seq_len`` (the assigned decode cells serve one new token against a cache
of seq_len).  Modality frontends are stubbed per the assignment: vlm gets
patch embeddings, audio gets precomputed mel-frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs import ShapeSpec
from ..models.lm import ModelCfg
from ..models.serve_model import init_cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelCfg, spec: ShapeSpec) -> Dict[str, Any]:
    """Training / prefill inputs for one assigned (arch x shape) cell."""
    b, s = spec.global_batch, spec.seq_len
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        # patch/frame embeddings from the (stubbed) vision frontend
        out["embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = sds((b, s), jnp.int32)
    if cfg.family == "audio":
        out["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if spec.kind == "train":
        out["labels"] = sds((b, s), jnp.int32)
    return out


def decode_specs(cfg: ModelCfg, spec: ShapeSpec,
                 policy=None) -> Tuple[Any, Any]:
    """(abstract_cache, token_specs) for the single-token serve step."""
    from ..core.transprecision import BF16
    b, s = spec.global_batch, spec.seq_len
    policy = policy or BF16
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s, policy=policy))
    if cfg.family == "vlm":
        tok = sds((b, 1, cfg.d_model), jnp.bfloat16)   # embeds path
    else:
        tok = sds((b, 1), jnp.int32)
    return cache, tok
