"""Serving launcher: batched requests through the three-stage engine.

``python -m repro.launch.serve --arch paper-edge --policy paper_edge_p8``
demonstrates the paper's deployment mode: an edge LM whose weights live in
posit P(8,2), decoded on load, serving a batch of concurrent requests with
continuous batching.  Underneath, serving is the disaggregated
``prefill -> insert -> generate`` API (``repro.serve.engine_api``):
prompts prefill in bucketed-length batches, insert into free decode
slots (scattered straight into pool pages on the paged layout), and one
jitted ``generate`` program ticks the whole batch.

``--async`` swaps the synchronous ``engine.serve`` loop for the threaded
orchestrator (``repro.serve.orchestrator``): a backpressured submission
queue with admission timeouts, Poisson arrivals at ``--rate`` req/s, and
host-side detokenize/streaming overlapped with device compute; it reports
TTFT and inter-token latency percentiles.  ``--overcommit`` (paged
layout) admits on current page demand instead of the worst case and
evicts/requeues the newest sequence if the pool runs dry.

Observability (``repro.obs``): ``--trace-out run.trace.json`` enables
the span tracer and writes a Chrome-trace file (open in
``chrome://tracing`` or https://ui.perfetto.dev) covering engine stage
dispatch/device-sync, orchestrator loop segments and the detokenizer
thread; a per-stage wall-clock breakdown table is printed at exit.
``--metrics-json metrics.json`` dumps the full metrics-registry
snapshot (counters, gauges, latency histograms with p50/p95/p99).

``--energy`` prints the modeled energy breakdown (``repro.obs.energy``):
each compiled engine stage costed by loop-aware HLO analysis, priced
with the paper's TALU per-MAC PDP row and a documented DRAM pJ/byte,
times the live per-stage call counters — joules total, uJ/token and the
per-stage precision mix.  ``--request-log requests.jsonl`` (async mode)
appends one JSON line per finished/rejected request with its full
lifecycle decomposition (queue wait / prefill / insert / decode), and
``--ttft-slo`` / ``--itl-slo`` (milliseconds) arm SLO-violation
counters in the registry.

Robustness (``repro.serve.faults`` / ``repro.serve.guard``):
``--fault-plan random:seed=3,n=6`` arms deterministic seed-driven fault
injection (stage errors/latency, pool-dry allocs, NaN-poisoned logits,
crashed workers under ``lethal=1``) together with the hardened
lifecycle — bounded exponential-backoff stage retries and the numeric
guard that quarantines non-finite logits and re-decodes the slot up a
precision-fallback ladder.  ``--deadline-s`` / ``--watchdog-s`` bound
per-request and scheduler-stall time in async mode, and ``--health``
prints the orchestrator's health snapshot (thread liveness, in-flight
depth, fault/guard counters) before exit.
"""
from __future__ import annotations

import argparse
import json
from time import perf_counter

import jax
import numpy as np

from ..configs import get_config
from ..core.transprecision import PRESETS
from ..models import lm
from ..obs import format_breakdown, stage_breakdown
from ..serve.engine import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-edge")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", default="paper_edge_p8",
                    choices=sorted(PRESETS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-format", default=None,
                    choices=["f32", "bf16", "posit16", "posit8", "posit4"],
                    help="KV-cache storage override (None: policy default)")
    ap.add_argument("--kv-layout", default=None, choices=["ring", "paged"],
                    help="KV-cache layout override (None: policy default)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged layout: tokens per page")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged layout: pool size incl. trash page "
                         "(None: full reservation)")
    ap.add_argument("--overcommit", action="store_true",
                    help="paged layout: admit on current page demand and "
                         "evict-and-requeue the newest sequence when the "
                         "pool runs dry (stats['evictions'])")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="drive the threaded orchestrator (backpressured "
                         "queue, Poisson arrivals, per-token streaming) "
                         "instead of the synchronous serve loop; prints "
                         "TTFT/ITL percentiles")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="async: offered load in requests/s "
                         "(0 = submit back-to-back)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="async: backpressure cap on requests in flight")
    ap.add_argument("--admission-timeout", type=float, default=60.0,
                    help="async: seconds submit may block on a full queue")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative greedy decode: gamma posit8 "
                         "draft steps + one target-precision verify per "
                         "round (token-identical to baseline greedy)")
    ap.add_argument("--gamma", type=int, default=4,
                    help="speculative: draft tokens per round")
    ap.add_argument("--draft-kv-format", default="posit8",
                    choices=["f32", "bf16", "posit16", "posit8", "posit4"],
                    help="speculative: draft-pass KV storage format")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome-trace "
                         "JSON (chrome://tracing / Perfetto) on exit; "
                         "also prints a per-stage wall-clock breakdown")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot (counters, "
                         "gauges, latency histograms) on exit")
    ap.add_argument("--energy", action="store_true",
                    help="print the modeled energy breakdown on exit "
                         "(TALU pJ/MAC x HLO FLOPs + DRAM pJ/byte x HBM "
                         "bytes, per stage call)")
    ap.add_argument("--request-log", default=None, metavar="PATH",
                    help="append one JSON line per terminal request with "
                         "its lifecycle decomposition (queue wait / "
                         "prefill / insert / decode)")
    ap.add_argument("--ttft-slo", type=float, default=None, metavar="MS",
                    help="TTFT SLO threshold in ms; violations counted "
                         "in the metrics registry (orch.slo.*)")
    ap.add_argument("--itl-slo", type=float, default=None, metavar="MS",
                    help="inter-token latency SLO threshold in ms")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="arm deterministic fault injection + the "
                         "hardened lifecycle (bounded stage retries; "
                         "numeric guard with precision-fallback re-decode "
                         "on the base engine).  SPEC is 'none', "
                         "'random:seed=3,n=6[,rounds=40][,slots=2]"
                         "[,lethal=1]' or a JSON fault-list file "
                         "(repro.serve.faults.FaultPlan.parse)")
    ap.add_argument("--deadline-s", type=float, default=None, metavar="S",
                    help="async: per-request deadline from submit; expiry "
                         "terminates the stream with error='deadline' and "
                         "reclaims its slot + pages")
    ap.add_argument("--watchdog-s", type=float, default=None, metavar="S",
                    help="async: fail all in-flight requests if the "
                         "scheduler makes no progress for this long")
    ap.add_argument("--health", action="store_true",
                    help="print the orchestrator health snapshot (JSON: "
                         "liveness, threads, in-flight depth, engine "
                         "occupancy, faults/guard counters) before exit; "
                         "sync mode prints the counter subset only")
    args = ap.parse_args()

    if args.speculative and args.temperature > 0:
        ap.error("--speculative is greedy-only (temperature 0)")

    cfg = get_config(args.arch, smoke=not args.full)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=args.batch, max_len=args.max_len,
                       temperature=args.temperature,
                       kv_format=args.kv_format, kv_layout=args.kv_layout,
                       page_size=args.page_size, num_pages=args.num_pages,
                       page_overcommit=args.overcommit)
    faults = retry = None
    guard = False
    if args.fault_plan:
        from ..serve.faults import FaultPlan, RetryPolicy
        faults = FaultPlan.parse(args.fault_plan)
        retry = RetryPolicy()
        # the numeric guard is a base-engine decode policy (speculative
        # verify-round quarantine is a ROADMAP follow-on)
        guard = not args.speculative
    if args.speculative:
        from ..serve.speculative import SpeculativeEngine
        engine = SpeculativeEngine(cfg, params, scfg, policy=args.policy,
                                   gamma=args.gamma,
                                   draft_kv_format=args.draft_kv_format,
                                   faults=faults, retry=retry)
    else:
        engine = ServingEngine(cfg, params, scfg, policy=args.policy,
                               faults=faults, retry=retry, guard=guard)
    if args.trace_out:
        engine.tracer.enable()
    rng = np.random.default_rng(0)
    if args.async_:
        return _serve_async(engine, cfg, rng, args)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, rng.integers(4, 17)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = perf_counter()
    stats = engine.serve(reqs)
    wall = perf_counter() - t0
    for r in reqs[:4]:
        print(f"req {r.uid}: {len(r.out_tokens)} tokens ->",
              r.out_tokens[:10], "...")
    if args.speculative:
        acc = stats["drafts_accepted"] / max(stats["drafts_proposed"], 1)
        spt = stats["decode_steps"] / max(stats["tokens"]
                                          - stats["prefills"], 1)
        print(f"speculative: gamma={args.gamma} acceptance={acc:.2f} "
              f"target steps/token={spt:.2f}")
    print("stats:", {k: (round(v, 2) if isinstance(v, float) else v)
                     for k, v in stats.items()})
    if args.request_log:    # sync path: dump the engine's own stamps
        with open(args.request_log, "a") as f:
            for r in reqs:
                f.write(json.dumps({"uid": r.uid, "error": r.error,
                                    "n_tokens": len(r.out_tokens),
                                    "lifecycle": r.timing}) + "\n")
        print(f"request log -> {args.request_log}")
    if args.health:    # sync path: no orchestrator, counters only
        c = engine.metrics.snapshot()["counters"]
        print("health:", json.dumps(
            {k: int(v) for k, v in sorted(c.items())
             if k.startswith(("faults.", "guard."))
             or k in ("stage.retries", "stage.retry_exhausted")}))
    _write_obs(engine, wall, args)


def _write_obs(engine, wall_s, args):
    """Dump trace / metrics files and print the stage breakdown."""
    if args.trace_out:
        print(format_breakdown(stage_breakdown(engine.tracer, wall_s)))
        engine.tracer.write_chrome_trace(args.trace_out)
        print(f"chrome trace -> {args.trace_out}")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(engine.metrics.snapshot(), f, indent=1)
        print(f"metrics snapshot -> {args.metrics_json}")
    if args.energy:
        from ..obs import EnergyAccountant, format_energy
        print(format_energy(EnergyAccountant(engine).breakdown()))


def _serve_async(engine, cfg, rng, args):
    import time

    from ..serve.orchestrator import (Orchestrator, OrchestratorConfig,
                                      StreamingRequest)
    ms = lambda v: v * 1e-3 if v is not None else None
    ocfg = OrchestratorConfig(max_queue=args.max_queue,
                              admission_timeout_s=args.admission_timeout,
                              detokenize=False,
                              deadline_s=args.deadline_s,
                              watchdog_s=args.watchdog_s,
                              ttft_slo_s=ms(args.ttft_slo),
                              itl_slo_s=ms(args.itl_slo),
                              request_log=args.request_log)
    sreqs = [StreamingRequest(
        rng.integers(0, cfg.vocab, rng.integers(4, 17)).tolist(),
        max_new=args.max_new) for _ in range(args.requests)]
    t0 = perf_counter()
    # no `with`: under a lethal fault plan a worker loop may die, and
    # __exit__ would re-raise its exception — we want to keep going and
    # report the health snapshot instead
    orch = Orchestrator(engine, ocfg)
    submitted = []
    try:
        for s in sreqs:
            try:
                ok = orch.submit(s)
            except RuntimeError as e:   # orchestrator went unhealthy
                print(f"submit refused: {e}")
                break
            if not ok:
                print("request timed out in admission; dropping")
                continue
            submitted.append(s)
            if args.rate > 0:
                time.sleep(float(rng.exponential(1.0 / args.rate)))
        # containment guarantees every submitted request reaches a
        # terminal state, so these waits cannot hang; the timeout is a
        # belt-and-suspenders bound for the launcher itself
        for s in submitted:
            s.wait(timeout=300.0)
        if args.health:
            print("health:", json.dumps(orch.health()))
    finally:
        try:
            orch.close()
        except RuntimeError as e:       # leaked-thread detection
            print(f"close: {e}")
    errs = {}
    for s in submitted:
        if s.error is not None:
            errs[s.error] = errs.get(s.error, 0) + 1
    if errs:
        print("terminal errors:", errs)
    wall = perf_counter() - t0
    for s in sreqs[:4]:
        print(f"stream: {len(s.out_tokens)} tokens ->",
              s.out_tokens[:10], "...")
    ttft = sorted(s.ttft_s for s in sreqs if s.ttft_s is not None)
    itl = sorted(g for s in sreqs for g in s.itl_s())
    pct = lambda xs, q: xs[min(int(q / 100 * len(xs)), len(xs) - 1)] * 1e3
    if ttft:
        print(f"TTFT p50/p99: {pct(ttft, 50):.1f}/{pct(ttft, 99):.1f} ms")
    if itl:
        print(f"ITL  p50/p99: {pct(itl, 50):.1f}/{pct(itl, 99):.1f} ms")
    print("orchestrator:", dict(orch.stats), "| engine:",
          {k: (round(v, 2) if isinstance(v, float) else v)
           for k, v in engine.stats.items()})
    if args.ttft_slo is not None or args.itl_slo is not None:
        c = engine.metrics.snapshot()["counters"]
        print("SLO:", {k: int(c.get(f"orch.slo.{k}", 0))
                       for k in ("ttft_violations", "ttft_total",
                                 "itl_violations", "itl_total")})
    if args.request_log:
        print(f"request log -> {args.request_log}")
    _write_obs(engine, wall, args)


if __name__ == "__main__":
    main()
