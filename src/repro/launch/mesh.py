"""Production mesh + sharding rules.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — CPU smoke tests see 1 device,
the dry-run sets XLA_FLAGS itself before any jax import.

Sharding is split into:
  * logical-axis rules (installed via ``models.common.axis_rules``) that the
    model's ``constrain`` calls resolve against, and
  * param/opt/batch/cache PartitionSpec builders keyed off leaf names —
    2-D sharding: matrix input dims -> "data" (FSDP), output dims ->
    "model" (TP), experts -> "model" (EP), KV-cache sequence -> "model".
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.lm import ModelCfg


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1-D data mesh (CPU tests, examples)."""
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# Logical-axis rules (consumed by models.common.constrain)
# ---------------------------------------------------------------------------

def train_rules(mesh: Mesh, *, global_batch: int, seq_shard: bool = True,
                heads_shard: bool = False) -> Dict[str, Any]:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = int(np.prod([mesh.shape[a] for a in batch_axes]))
    batch = batch_axes if global_batch % bsz == 0 else None
    return {
        "batch": batch,
        "seq": "model" if seq_shard else None,   # sequence-parallel residual
        "heads": "model" if heads_shard else None,
        "ffn": "model",
        "vocab": "model",
        "expert": "model",
        "kv_seq": "model",
    }


def serve_rules(mesh: Mesh, *, global_batch: int) -> Dict[str, Any]:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = int(np.prod([mesh.shape[a] for a in batch_axes]))
    batch = batch_axes if global_batch % bsz == 0 else None
    return {
        "batch": batch,
        "seq": None,
        "heads": None,
        "ffn": "model",
        "vocab": "model",
        "expert": "model",
        "kv_seq": "model",
    }


# ---------------------------------------------------------------------------
# Param / optimizer / batch / cache PartitionSpecs
# ---------------------------------------------------------------------------

# weight-leaf name -> (spec for the trailing dims); leading stack axes get None
_MAT_IN_OUT = {"wq", "wk", "wv", "wi", "wx", "wy", "in_proj", "w_a", "w_x",
               "wq_x", "wk_x", "wv_x"}
_MAT_OUT_IN = {"wo", "wo_mlp", "w_out", "out_proj", "wo_x"}


def _leaf_spec(path: str, shape, fsdp, model) -> P:
    """Trailing-dims partition for one param leaf (by its dict key name)."""
    parts = path.split("/")
    name = parts[-1]
    nd = len(shape)
    # packed QuantizedTensor leaves: codes shard like the weight itself,
    # per-channel scales shard on their (last) channel dim
    if name == "data" and len(parts) >= 2:
        name = parts[-2]
    elif name == "scale":
        parent = parts[-2] if len(parts) >= 2 else ""
        last = model if (parent in _MAT_IN_OUT or parent in _MAT_OUT_IN
                         or parent in ("wi", "wo")) else None
        if parent in _MAT_OUT_IN:   # output dim is the param's fsdp dim
            last = fsdp
        return P(*([None] * (nd - 1)), last)
    if name == "embed":                       # (vocab, d)
        return P(model, fsdp)
    if name == "lm_head":                     # (d, vocab)
        return P(fsdp, model)
    if name == "router":                      # (d, E) — replicate E (tiny)
        return P(*([None] * (nd - 2)), fsdp, None)
    if name in ("wi", "wo") and nd >= 3 and "moe" in path:
        # MoE expert weights (E, d, f) / (E, f, d): experts on model (EP)
        lead = [None] * (nd - 3)
        if name == "wi":
            return P(*lead, model, fsdp, None)
        return P(*lead, model, None, fsdp)
    if name == "conv_w":                      # (K, ch): channels follow model
        return P(*([None] * (nd - 1)), model)
    if name in _MAT_IN_OUT and nd >= 2:
        return P(*([None] * (nd - 2)), fsdp, model)
    if name in _MAT_OUT_IN and nd >= 2:
        return P(*([None] * (nd - 2)), model, fsdp)
    # vectors/norms/scalars (ln, *_norm, A_log, D, dt_bias, Lambda, b_*)
    return P(*([None] * nd))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(abstract_params, *, fsdp: Optional[str] = "data",
                model: Optional[str] = "model"):
    """PartitionSpec pytree matching ``init_params(..., abstract=True)``.

    MoE expert weights live under a "moe" key so the EP rule can find them;
    everything else dispatches on the leaf name.  ``fsdp=None`` replicates
    the weight input dims (serving mode).

    Packed QuantizedTensor weights emit ONE spec at the QT position (a
    pytree *prefix*: jit broadcasts it over (data, scale); the scale's
    broadcast dims are size-1 so the data spec is valid for both).
    """
    from ..core.quant import QuantizedTensor

    def spec(kp, leaf):
        shape = leaf.data.shape if isinstance(leaf, QuantizedTensor) \
            else leaf.shape
        return _leaf_spec(_path_str(kp), shape, fsdp, model)

    return jax.tree_util.tree_map_with_path(
        spec, abstract_params,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))


def opt_specs(pspecs):
    """Optimizer-state specs: every moment/master leaf shards like its param."""
    return {"step": P(), "mu": pspecs, "nu": pspecs, "master": pspecs}


def batch_specs(cfg: ModelCfg, rules: Dict[str, Any], keys=None):
    b = rules.get("batch")
    out = {"tokens": P(b, None), "labels": P(b, None),
           "embeds": P(b, None, None), "frames": P(b, None, None)}
    if keys is None:
        keys = {"tokens", "labels"}
        if cfg.family == "vlm":
            keys = {"embeds", "labels"}
        if cfg.family == "audio":
            keys |= {"frames"}
    return {k: out[k] for k in keys}


def cache_specs(abstract_cache, cfg: ModelCfg, rules: Dict[str, Any]):
    """Decode-cache specs: KV sequence on "model", batch on data axes."""
    b = rules.get("batch")
    kv = rules.get("kv_seq")
    model = "model"

    def spec(kp, leaf):
        path = _path_str(kp)
        name = path.split("/")[-1]
        nd = len(leaf.shape)
        stacked = path.startswith("blocks")   # leading period-stack axis
        lead = (None,) if stacked else ()
        if name == "pos":
            return P()
        if name == "memory":                  # (B, enc_seq, d)
            return P(b, None, None)
        if name in ("k", "v"):                # (B, W, nkv, hd|codes)
            return P(*lead, b, kv, None, None)
        if name in ("k_scale", "v_scale"):    # (B, W, nkv) packed-KV scales
            return P(*lead, b, kv, None)
        if name in ("xk", "xv"):              # (B, enc_seq, nkv, hd)
            return P(*lead, b, None, None, None)
        if name == "state":                   # (B, nh, hd, ds)
            return P(*lead, b, model, None, None)
        if name == "conv":                    # (B, K-1, ch)
            return P(*lead, b, None, model)
        if name == "h":                       # (B, width)
            return P(*lead, b, model)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, abstract_cache)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
