"""Drive the full dry-run matrix: every assigned (arch x shape) cell on the
single-pod (16,16) and multi-pod (2,16,16) production meshes.

Each cell runs in its own subprocess (jax device count is locked at first
init; per-cell isolation also bounds compiler memory).  Existing result
JSONs are skipped, so the sweep is resumable — rerun after a fix and only
failed cells recompile.

  PYTHONPATH=src python -m repro.launch.dryrun_all [--mesh pod1 pod2] \
      [--out benchmarks/results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from ..configs import cells


def run_cell(arch, shape, mesh, out, extra=()):
    name = f"{arch}_{shape}_{mesh}"
    path = os.path.join(out, name + ".json")
    if os.path.exists(path):
        return "cached", 0.0
    t0 = time.time()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", out, *extra],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    dt = time.time() - t0
    if proc.returncode != 0:
        tail = proc.stderr.strip().splitlines()[-12:]
        json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                   "error": "\n".join(tail)}, open(path + ".err", "w"),
                  indent=1)
        return "FAIL", dt
    return "ok", dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", nargs="+", default=["pod1", "pod2"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--archs", nargs="*", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    results = {}
    t00 = time.time()
    for arch, shape, ok, why in cells():
        if args.archs and arch not in args.archs:
            continue
        for mesh in args.mesh:
            status, dt = run_cell(arch, shape, mesh, args.out)
            results[(arch, shape, mesh)] = status
            print(f"[{time.time() - t00:7.0f}s] {status:6s} "
                  f"{arch} {shape} {mesh} ({dt:.0f}s)", flush=True)
    fails = [k for k, v in results.items() if v == "FAIL"]
    print(f"\ndone: {len(results) - len(fails)}/{len(results)} ok")
    for k in fails:
        print("FAILED:", k)
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
