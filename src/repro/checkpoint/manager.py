"""Fault-tolerant checkpointing: atomic, keep-k, async, resumable.

Layout:  <dir>/step_<n>/arrays.npz + tree.json  (+ .COMMIT marker)

* atomic    — writes go to ``step_<n>.tmp`` then ``os.replace`` + a COMMIT
              marker file; a crash mid-write can never produce a checkpoint
              that ``latest_step`` would pick up.
* keep-k    — old committed steps beyond ``keep`` are garbage-collected.
* async     — ``save(..., blocking=False)`` snapshots to host memory
              (device_get) synchronously, then serializes on a background
              thread so the train loop only blocks for the D2H copy.
* sharded   — leaves are fetched with ``jax.device_get`` (works for sharded
              GDA-style arrays: XLA gathers), and restores are re-sharded by
              the caller's ``jax.device_put`` against the current mesh, so a
              restart may use a DIFFERENT topology (elastic scaling).

Pytrees are flattened to ``path -> array`` with a JSON treedef sidecar, so
checkpoints are inspectable with plain numpy.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_SEP = "|"


def _savable(a: np.ndarray) -> np.ndarray:
    """npz can't round-trip ml_dtypes (bf16/fp8) — store them as f32;
    restore casts back to the template dtype."""
    a = np.asarray(a)
    if a.dtype.kind == "V" or a.dtype.name in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return a.astype(np.float32)
    return a


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        flat[key] = leaf
    return flat


def save_pytree(tree, path: str):
    """Atomic single-file save of a pytree of arrays."""
    tmp = path + ".tmp"
    flat = {k: _savable(jax.device_get(v)) for k, v in _flatten(tree).items()}
    treedef = jax.tree_util.tree_structure(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, __treedef__=np.frombuffer(
            str(treedef).encode(), dtype=np.uint8), **flat)
    os.replace(tmp, path)


def load_pytree(template, path: str):
    """Load into the structure of ``template`` (shapes must match)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files if k != "__treedef__"}
    tmpl_flat = _flatten(template)
    missing = set(tmpl_flat) - set(flat)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = [_SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp)
            for kp, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
    new_leaves = [flat[k].astype(np.asarray(l).dtype) if hasattr(l, "dtype")
                  else flat[k] for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ---- discovery ----
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                full = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(full, "COMMIT")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ---- save ----
    def _write(self, flat_np: Dict[str, np.ndarray], step: int,
               meta: Dict[str, Any]):
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        try:
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                np.savez(f, **flat_np)
            json.dump(meta, open(os.path.join(tmp, "meta.json"), "w"))
            open(os.path.join(tmp, "COMMIT"), "w").write(str(time.time()))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
        except BaseException as e:          # surfaced on next wait()/save()
            self._error = e

    def save(self, tree, step: int, blocking: bool = True,
             meta: Optional[Dict[str, Any]] = None):
        """Snapshot to host, then serialize (optionally on a worker thread)."""
        self.wait()
        flat_np = {k: _savable(jax.device_get(v))
                   for k, v in _flatten(tree).items()}
        meta = dict(meta or {}, step=step, time=time.time())
        if blocking:
            self._write(flat_np, step, meta)
            self.check()
        else:
            self._thread = threading.Thread(
                target=self._write, args=(flat_np, step, meta), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.check()

    def check(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {err!r}") from err

    # ---- restore ----
    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        keys = [_SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in kp)
                for kp, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
        leaves, treedef = jax.tree_util.tree_flatten(template)
        new = []
        for k, l in zip(keys, leaves):
            arr = flat[k]
            if hasattr(l, "dtype"):
                arr = arr.astype(l.dtype)
            new.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, new)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                                shardings)
        meta = json.load(open(os.path.join(path, "meta.json")))
        return tree, meta

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
