"""Deterministic, elastic, checkpointable data pipeline.

Requirements at 1000-node scale:

* deterministic   — batch content is a pure function of (seed, step), so a
                    restart (or a replayed straggler) regenerates identical
                    batches with no coordination;
* elastic         — sharding is derived from (step, host_id, world_size) at
                    call time: if the fleet is resized, every host still
                    draws a disjoint slice of the SAME global batch, so
                    elastic rescaling does not perturb the data order;
* checkpointable  — pipeline state is just the integer ``step`` (stored in
                    the optimizer state), no iterator pickling.

Synthetic corpora here (zipf-distributed "language" with a learnable
next-token structure, so loss actually falls); the interface (``global_batch
(step)`` / ``host_batch(step, host, n_hosts)``) is what a real tokenized-
shard reader would implement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import ModelCfg


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    vocab: int = 256
    zipf_a: float = 1.2


class SyntheticLM:
    """Deterministic synthetic LM stream with learnable structure:
    ``x[t+1] = (a * x[t] + b) % vocab`` segments with zipf-sampled (a, b) —
    a model that learns the affine map drives loss toward 0."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelCfg] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    # ---- core determinism: batch = f(seed, step) ----
    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(np.uint64(c.seed * 1_000_003 + step))
        a = 1 + 2 * rng.integers(0, 16, (c.global_batch, 1))   # odd multipliers
        b = rng.integers(0, c.vocab, (c.global_batch, 1))
        x0 = rng.integers(0, c.vocab, (c.global_batch, 1))
        t = np.arange(c.seq_len)[None, :]
        # affine orbit; cheap vectorized closed form via repeated squaring is
        # overkill — iterate (seq_len is bounded)
        toks = np.empty((c.global_batch, c.seq_len), np.int64)
        cur = x0[:, 0]
        for i in range(c.seq_len):
            toks[:, i] = cur
            cur = (a[:, 0] * cur + b[:, 0]) % c.vocab
        labels = np.concatenate([toks[:, 1:], cur[:, None]], axis=1)
        batch = {"tokens": toks.astype(np.int32),
                 "labels": labels.astype(np.int32)}
        mc = self.model_cfg
        if mc is not None and mc.family == "vlm":
            emb_rng = np.random.default_rng(np.uint64(c.seed + 7 + step))
            batch["embeds"] = emb_rng.standard_normal(
                (c.global_batch, c.seq_len, mc.d_model)).astype(np.float32)
            del batch["tokens"]
        if mc is not None and mc.family == "audio":
            emb_rng = np.random.default_rng(np.uint64(c.seed + 13 + step))
            batch["frames"] = emb_rng.standard_normal(
                (c.global_batch, mc.enc_seq, mc.d_model)).astype(np.float32)
        return batch

    # ---- elastic sharding: world size resolved per call ----
    def host_batch(self, step: int, host: int, n_hosts: int
                   ) -> Dict[str, np.ndarray]:
        gb = self.global_batch(step)
        bsz = self.cfg.global_batch
        assert bsz % n_hosts == 0, (bsz, n_hosts)
        per = bsz // n_hosts
        return {k: v[host * per:(host + 1) * per] for k, v in gb.items()}

    def __call__(self, step: int) -> Dict[str, jnp.ndarray]:
        return {k: jnp.asarray(v) for k, v in self.global_batch(step).items()}


def make_pipeline(model_cfg: ModelCfg, *, global_batch: int, seq_len: int,
                  seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        DataConfig(seed=seed, global_batch=global_batch, seq_len=seq_len,
                   vocab=model_cfg.vocab), model_cfg)
