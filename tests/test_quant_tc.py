"""QuantizedTensor / fake-quant / TC policy tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.formats import get
from repro.core.transprecision import (BF16, MIXED_TC, PAPER_EDGE, TCPolicy,
                                       get_policy)


@pytest.mark.parametrize("fmt", ["posit8_2", "posit16_2", "int8", "fp8_e4m3", "bf16"])
def test_quant_roundtrip_error_bounded(fmt):
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.02, (64, 64)).astype(np.float32)  # NN-weight-like
    qt = quant.quantize(x, fmt)
    back = np.asarray(quant.dequantize(qt))
    rel = np.abs(back - x) / (np.abs(x) + 1e-8)
    med = np.median(rel)
    # 8-bit formats: few-percent median error; 16-bit: much tighter
    assert med < (0.05 if get(fmt).bits <= 8 else 0.005), (fmt, med)


def test_posit_scale_is_exact_power_of_two():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 3e-3, (256,)).astype(np.float32)
    qt = quant.quantize(x, "posit8_2")
    s = float(np.asarray(qt.scale).ravel()[0])
    assert s == 2.0 ** round(np.log2(s))


def test_posit_beats_fp8_on_small_values():
    """The paper's §II claim: posit preserves small magnitudes that fp8
    flushes to zero / coarsens (the 0.00024 example, distribution-shaped).
    Raw format property -> unscaled storage for both."""
    rng = np.random.default_rng(2)
    x = rng.normal(0, 0.01, (4096,)).astype(np.float32)  # gradients-like
    qt = quant.quantize(x, "posit8_2", scaled=False)
    err_p = float(jnp.mean((quant.dequantize(qt) - x) ** 2))
    err_f = float(quant.quantization_mse(x, "fp8_e4m3"))
    assert err_p < err_f
    # and with tensor scaling enabled posit8 is at least as good as fp8
    err_ps = float(quant.quantization_mse(x, "posit8_2"))
    assert err_ps <= err_f


def test_quantized_tensor_is_pytree():
    x = jnp.ones((8, 8))
    qt = quant.quantize(x, "posit8_2")
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(qt.data), np.asarray(qt2.data))
    # jit through it
    f = jax.jit(lambda q: quant.dequantize(q).sum())
    assert np.isfinite(float(f(qt)))


def test_fake_quant_ste_gradient():
    x = jnp.linspace(-1, 1, 32)
    g = jax.grad(lambda v: quant.fake_quant(v, "posit8_2").sum())(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)  # straight-through


def test_policy_role_layer_node_resolution():
    p = TCPolicy(
        name="t", mlp_weights="posit8_2",
        layer_overrides=((3, "mlp_weights", "posit16_2"),),
        node_overrides=(("lm_head", "bf16"),),
    )
    assert p.fmt_for("mlp_weights") == "posit8_2"
    assert p.fmt_for("mlp_weights", layer=3) == "posit16_2"
    assert p.fmt_for("mlp_weights", layer=2) == "posit8_2"
    assert p.fmt_for("mlp_weights", node="lm_head") == "bf16"
    assert p.fmt_for("attn_weights") is None
    assert hash(p)  # usable as a jit static arg


def test_policy_quantize_weight_shapes_and_finite():
    w = jnp.asarray(np.random.default_rng(0).normal(0, 0.02, (32, 64)), jnp.float32)
    for pol in [BF16, PAPER_EDGE, MIXED_TC]:
        out = pol.quantize_weight(w, "mlp_weights", layer=0)
        assert out.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(out)))
        if pol is BF16:
            np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


def test_paper_edge_preset_is_p8():
    p = get_policy("paper_edge_p8")
    assert p.mlp_weights == "posit8_2"
    assert p.kv_cache == "posit8_2"
