"""Loop-aware HLO cost parser: correctness against XLA's own cost analysis
on loop-free modules, and scan==unrolled invariance (the property that
justifies using it for the scanned production programs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze, normalize_cost_analysis


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_loop_free_matches_cost_analysis():
    def f(x, w1, w2):
        h = jnp.tanh(x @ w1)
        return jnp.sum((h @ w2) ** 2)

    g = jax.jit(jax.grad(f, argnums=(1, 2)))
    s = jax.ShapeDtypeStruct
    c = g.lower(s((512, 256), jnp.float32), s((256, 1024), jnp.float32),
                s((1024, 128), jnp.float32)).compile()
    mine = analyze(c.as_text())
    # newer JAX returns a list of per-module dicts; normalize either form
    ca = normalize_cost_analysis(c.cost_analysis())
    assert abs(mine["flops"] / ca["flops"] - 1) < 0.05
    assert abs(mine["bytes"] / ca["bytes accessed"] - 1) < 0.25


@pytest.mark.parametrize("n", [3, 8])
def test_scan_equals_unrolled(n):
    def body(c, _):
        return jnp.tanh(c @ c), None

    def scanned(x):
        return jax.lax.scan(body, x, None, length=n)[0]

    def unrolled(x):
        for _ in range(n):
            x = jnp.tanh(x @ x)
        return x

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fs = analyze(_compile(scanned, s).as_text())
    fu = analyze(_compile(unrolled, s).as_text())
    assert abs(fs["flops"] / fu["flops"] - 1) < 0.02
    expected = n * 2 * 128 ** 3
    assert abs(fs["flops"] / expected - 1) < 0.02
    assert abs(fs["bytes"] / fu["bytes"] - 1) < 0.35


def test_nested_loops_multiply():
    def g(x):
        def outer(c, _):
            def inner(a, _):
                return a @ c, None
            a, _ = jax.lax.scan(inner, c, None, length=4)
            return jnp.tanh(a), None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = _compile(g, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze(c.as_text())
    expected = 3 * 4 * 2 * 128 ** 3
    assert abs(r["flops"] / expected - 1) < 0.02


def test_collectives_counted_with_trip_multiplicity():
    import os
    # 8 sub-devices exist only if the test session was started that way;
    # instead exercise via a 1-device mesh psum inside scan (still emits
    # an all-reduce on CPU SPMD when sharded) — fall back to structure-only
    hlo = """
HloModule m, is_scheduled=true

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %c1 = s32[] constant(1)
  %ip = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[128]) tuple(%ip, %ar)
}

%cond (p2: (s32[], f32[128])) -> pred[] {
  %p2 = (s32[], f32[128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[128]) -> (s32[], f32[128]) {
  %a = f32[128]{0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(%z, %a)
  ROOT %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    r = analyze(hlo)
    assert r["collectives"]["all-reduce"]["count"] == 7
    assert r["collectives"]["all-reduce"]["bytes"] == 7 * 128 * 4
