"""Self-speculative decoding: draft-policy derivation, chunked append
kernels vs refs, verify_step bit-identity, KV rollback invariants
(post-rollback caches bit-identical to never-drafted ones), allocator
edge-case hardening, per-request temperature, and the acceptance
criterion — speculative greedy streams token-identical to baseline greedy
across layouts and KV formats with < 1 target step per emitted token."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.formats import POSIT8_2
from repro.core.transprecision import BF16, draft_policy
from repro.kernels import kv_cache as kvk
from repro.kernels import paged_kv as pkv
from repro.models import lm
from repro.models.serve_model import decode_step, init_cache, prefill, \
    verify_step
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.serve.paged import PageAllocator
from repro.serve.speculative import SpeculativeEngine


# ---------------------------------------------------------------------------
# Allocator edge cases (satellite: raise clearly, never corrupt state)
# ---------------------------------------------------------------------------

def test_allocator_free_trash_page_raises():
    a = PageAllocator(num_pages=4, page_size=2)
    with pytest.raises(ValueError, match="trash page"):
        a.free([0])
    assert a.num_free == 3                       # untouched


def test_allocator_free_out_of_range_raises():
    a = PageAllocator(num_pages=4, page_size=2)
    with pytest.raises(ValueError, match="out-of-range"):
        a.free([4])
    with pytest.raises(ValueError, match="out-of-range"):
        a.free([-1])                             # would wrap under numpy
    assert a.num_free == 3


def test_allocator_double_free_is_atomic():
    """A free list containing a double free must raise BEFORE any
    refcount moves — the valid pages in the same call stay allocated."""
    a = PageAllocator(num_pages=5, page_size=2)
    pages = a.alloc(3)
    with pytest.raises(ValueError, match="double free"):
        a.free(pages + [pages[0]])               # duplicate within one call
    assert a.num_free == 1                       # nothing was freed
    assert all(a.ref_count(p) == 1 for p in pages)
    a.free(pages)                                # still fully freeable
    assert a.num_free == 4


def test_allocator_fork_after_free_raises():
    a = PageAllocator(num_pages=4, page_size=2)
    p = a.alloc(2)
    a.free(p)
    with pytest.raises(ValueError, match="not allocated"):
        a.fork(p)
    with pytest.raises(ValueError, match="trash page"):
        a.fork([0])
    with pytest.raises(ValueError, match="out-of-range"):
        a.fork([9])
    assert a.num_free == 3


def test_allocator_fork_atomic_on_partial_failure():
    a = PageAllocator(num_pages=5, page_size=2)
    keep = a.alloc(2)
    dropped = a.alloc(1)
    a.free(dropped)
    with pytest.raises(ValueError):
        a.fork(keep + dropped)                   # last page is freed
    assert all(a.ref_count(p) == 1 for p in keep)  # no refcount leak


# ---------------------------------------------------------------------------
# Draft-policy derivation
# ---------------------------------------------------------------------------

def test_draft_policy_derivation():
    target = dataclasses.replace(BF16, kv_format="f32", kv_layout="paged",
                                 layer_overrides=((0, "mlp_weights",
                                                   "posit16_2"),),
                                 name="tgt")
    d = draft_policy(target)
    assert d.attn_weights == "posit8_2" and d.mlp_weights == "posit8_2"
    assert d.kv_format == "posit8"
    assert d.kv_layout == "ring"                 # draft cache never pages
    assert d.layer_overrides == ()               # uniformly cheap
    assert "draft" in d.name
    wide = draft_policy(target, weights_fmt="posit16_2",
                        kv_format="posit16")
    assert wide.kv_format == "posit16" and wide.mlp_weights == "posit16_2"


# ---------------------------------------------------------------------------
# Chunked append kernels vs jnp oracles (interpret mode)
# ---------------------------------------------------------------------------

def test_kv_append_rows_kernel_bit_exact():
    rng = np.random.default_rng(5)
    b, w, nkv, hd, t = 2, 16, 2, 8, 3
    fmt = POSIT8_2
    kc = jnp.zeros((b, w, nkv, hd), fmt.storage_dtype)
    ks = jnp.ones((b, w, nkv), jnp.float32)
    kn = jnp.asarray(rng.normal(0, 1, (b, t, nkv, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(0, 1, (b, t, nkv, hd)), jnp.float32)
    pos = jnp.asarray([3, 11], jnp.int32)
    got = kvk.kv_append_rows(kc, ks, kc, ks, kn, vn, pos, fmt,
                             interpret=True)
    want = kvk.kv_append_rows_ref(kc, ks, kc, ks, kn, vn, pos, fmt)
    for g_, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_))


def test_kv_append_rows_matches_sequential_single_appends():
    """T-row chunk append == T single-row appends (same codec, same
    rows): the property verify_step's bit-identity rests on."""
    rng = np.random.default_rng(6)
    b, w, nkv, hd, t = 2, 12, 2, 8, 4
    fmt = POSIT8_2
    kc = jnp.zeros((b, w, nkv, hd), fmt.storage_dtype)
    ks = jnp.ones((b, w, nkv), jnp.float32)
    kn = jnp.asarray(rng.normal(0, 1, (b, t, nkv, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(0, 1, (b, t, nkv, hd)), jnp.float32)
    pos = jnp.asarray([0, 5], jnp.int32)
    chunk = kvk.kv_append_rows_ref(kc, ks, kc, ks, kn, vn, pos, fmt)
    seq = (kc, ks, kc, ks)
    for i in range(t):
        seq = kvk.kv_append_ref(*seq, kn[:, i:i + 1], vn[:, i:i + 1],
                                pos + i, fmt)
    for c_, s_ in zip(chunk, seq):
        np.testing.assert_array_equal(np.asarray(c_), np.asarray(s_))


def test_paged_append_rows_kernel_bit_exact():
    rng = np.random.default_rng(7)
    b, nkv, hd, ps, npages, t = 2, 2, 8, 4, 6, 3
    fmt = POSIT8_2
    kc = jnp.zeros((npages * ps, nkv, hd), fmt.storage_dtype)
    ks = jnp.ones((npages * ps, nkv), jnp.float32)
    kn = jnp.asarray(rng.normal(0, 1, (b, t, nkv, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(0, 1, (b, t, nkv, hd)), jnp.float32)
    table = jnp.asarray([[1, 2, 0], [3, 4, 5]], jnp.int32)
    dst = pkv.flat_dst_rows_chunk(table, jnp.asarray([2, 6]), t, ps)
    # chunk rows match the per-token row computation
    for ti in range(t):
        one = pkv.flat_dst_rows(table, jnp.asarray([2 + ti, 6 + ti]), ps)
        np.testing.assert_array_equal(np.asarray(dst[:, ti]),
                                      np.asarray(one))
    got = pkv.paged_kv_append_rows(kc, ks, kc, ks, kn, vn, dst, fmt,
                                   interpret=True)
    want = pkv.paged_kv_append_rows_ref(kc, ks, kc, ks, kn, vn, dst, fmt)
    for g_, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_))


# ---------------------------------------------------------------------------
# verify_step bit-identity + engine stream equivalence
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n) for n in (4, 11, 7)]
    return cfg, params, prompts


@pytest.mark.parametrize("layout", ["ring", "paged"])
def test_verify_step_bit_identical_to_sequential(smoke_model, layout):
    """One (B, T) verify pass == T sequential decode_steps: same logits,
    same cache rows (posit8 target)."""
    cfg, params, prompts = smoke_model
    pol = dataclasses.replace(BF16, kv_format="posit8", kv_layout=layout,
                              kv_page_size=4, name=f"vt_{layout}")
    toks = jnp.asarray(prompts[2], jnp.int32)[None, :]
    l0, cache = prefill(params, {"tokens": toks}, cfg, 32, pol)
    chunk = [int(np.argmax(np.asarray(l0)[0][: cfg.vocab]))]
    seq_logits, c = [], cache
    for _ in range(4):
        lg, c = decode_step(params, c, jnp.asarray([[chunk[-1]]], jnp.int32),
                            cfg, pol)
        seq_logits.append(np.asarray(lg)[0])
        chunk.append(int(np.argmax(np.asarray(lg)[0][: cfg.vocab])))
    _, cache2 = prefill(params, {"tokens": toks}, cfg, 32, pol)
    lv, c2 = verify_step(params, cache2, jnp.asarray([chunk[:4]], jnp.int32),
                         cfg, pol)
    np.testing.assert_array_equal(np.asarray(lv)[0], np.stack(seq_logits))
    for leaf_seq, leaf_chunk in zip(jax.tree_util.tree_leaves(dict(c)),
                                    jax.tree_util.tree_leaves(dict(c2))):
        np.testing.assert_array_equal(np.asarray(leaf_seq),
                                      np.asarray(leaf_chunk))


def _never_drafted_cache(cfg, params, prompt, tokens, pol, max_len):
    """Target cache after committing ``tokens[:-1]`` the plain way."""
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    _, cache = prefill(params, {"tokens": toks}, cfg, max_len, pol)
    cache["pos"] = jnp.broadcast_to(cache["pos"], (1,)).astype(jnp.int32)
    for t in tokens[:-1]:
        _, cache = decode_step(params, cache,
                               jnp.asarray([[t]], jnp.int32), cfg, pol)
    return cache


@pytest.mark.parametrize("kvf", ["f32", "posit8"])
def test_ring_rollback_bit_identical_to_never_drafted(smoke_model, kvf):
    """Acceptance-critical invariant: after any number of speculative
    rounds the ring cache equals, bit for bit, a cache that decoded the
    committed tokens one at a time and never drafted."""
    cfg, params, prompts = smoke_model
    scfg = ServeConfig(max_batch=1, max_len=32, kv_format=kvf,
                       kv_layout="ring")
    eng = SpeculativeEngine(cfg, params, scfg, gamma=3)
    req = Request(uid=0, prompt=prompts[0], max_new=6)
    eng.add_request(req)
    while not req.done and len(req.out_tokens) < 4:
        eng.step()
    pol = eng.policy
    ref = _never_drafted_cache(cfg, params, prompts[0], req.out_tokens,
                               pol, 32)
    for got, want in zip(jax.tree_util.tree_leaves(dict(eng.cache)),
                         jax.tree_util.tree_leaves(dict(ref))):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_rollback_bit_identical_and_frees_orphans(smoke_model):
    """Paged rollback truncates the page list, returns orphaned pages to
    the allocator, and scrubs rolled-back pool rows so the slot's
    allocated pages are bit-identical to a never-drafted run's."""
    cfg, params, prompts = smoke_model
    scfg = ServeConfig(max_batch=1, max_len=32, kv_format="posit8",
                       kv_layout="paged", page_size=4)
    eng = SpeculativeEngine(cfg, params, scfg, gamma=3)
    req = Request(uid=0, prompt=prompts[0], max_new=6)
    eng.add_request(req)
    while not req.done and len(req.out_tokens) < 4:
        eng.step()
    n = int(eng.slot_pos[0])
    # page accounting: exactly the committed length's pages stay live
    assert len(eng.slot_pages[0].pages) == -(-n // 4)
    assert eng.allocator.live_pages == len(eng.slot_pages[0].pages)
    ref = _never_drafted_cache(cfg, params, prompts[0], req.out_tokens,
                               eng.policy, 32)
    # compare the slot-logical view (gathered pages) — physical page ids
    # differ between the engine pool and the identity-table reference
    ps = 4
    for blk_e, blk_r in zip(eng.cache["blocks"], ref["blocks"]):
        for name in ("k", "v", "k_scale", "v_scale"):
            for L in range(blk_e[name].shape[0]):
                got = pkv.gather_pages(blk_e[name][L],
                                       eng.cache["page_table"], ps)
                want = pkv.gather_pages(blk_r[name][L],
                                        ref["page_table"], ps)
                np.testing.assert_array_equal(
                    np.asarray(got)[0, :n], np.asarray(want)[0, :n],
                    err_msg=f"{name} layer {L}")
                # rolled-back rows within still-allocated pages are
                # scrubbed to init values
                tail = np.asarray(got)[0, n: len(eng.slot_pages[0].pages) * ps]
                init = 1.0 if name.endswith("_scale") else 0
                assert (tail == init).all(), f"{name} layer {L} not scrubbed"


@pytest.mark.parametrize("layout", ["ring", "paged"])
@pytest.mark.parametrize("kvf", ["f32", "posit16", "posit8"])
def test_speculative_stream_identical_to_baseline(smoke_model, kvf, layout):
    """THE acceptance criterion: speculative greedy decode emits
    token-for-token the same streams as baseline greedy decode, for both
    layouts and every posit/f32 target format, under continuous batching
    with slot reuse — while doing strictly fewer target decode steps than
    tokens (the speedup exists)."""
    cfg, params, prompts = smoke_model
    scfg = ServeConfig(max_batch=2, max_len=48, kv_format=kvf,
                       kv_layout=layout, page_size=4)
    base = ServingEngine(cfg, params, scfg)
    reqs_b = [Request(uid=i, prompt=p, max_new=5)
              for i, p in enumerate(prompts)]
    base.serve(reqs_b)
    spec = SpeculativeEngine(cfg, params, scfg, gamma=3)
    reqs_s = [Request(uid=i, prompt=p, max_new=5)
              for i, p in enumerate(prompts)]
    stats = spec.serve(reqs_s)
    assert [r.out_tokens for r in reqs_s] == [r.out_tokens for r in reqs_b]
    decode_tokens = stats["tokens"] - stats["prefills"]
    assert stats["decode_steps"] < decode_tokens      # > 1 token per verify
    assert 0 < stats["drafts_accepted"] <= stats["drafts_proposed"]
    if layout == "paged":
        assert spec.allocator.live_pages == 0         # no page leaks
        spec.allocator.assert_consistent()


def test_speculative_eos_stream_identical(smoke_model):
    """EOS inside an accepted draft run truncates exactly like baseline."""
    cfg, params, prompts = smoke_model
    scfg = ServeConfig(max_batch=2, max_len=48, kv_format="f32",
                       kv_layout="ring", eos_id=29)
    base = ServingEngine(cfg, params, scfg)
    reqs_b = [Request(uid=i, prompt=p, max_new=8)
              for i, p in enumerate(prompts)]
    base.serve(reqs_b)
    spec = SpeculativeEngine(cfg, params, scfg, gamma=3)
    reqs_s = [Request(uid=i, prompt=p, max_new=8)
              for i, p in enumerate(prompts)]
    spec.serve(reqs_s)
    assert [r.out_tokens for r in reqs_s] == [r.out_tokens for r in reqs_b]


def test_speculative_rejects_non_greedy(smoke_model):
    cfg, params, prompts = smoke_model
    scfg = ServeConfig(max_batch=1, max_len=32)
    eng = SpeculativeEngine(cfg, params, scfg, gamma=2)
    hot = Request(uid=0, prompt=prompts[0], max_new=4, temperature=0.7)
    with pytest.raises(ValueError, match="greedy-only"):
        eng.add_request(hot)
    stats = eng.serve([hot])                 # queue path: rejected cleanly
    assert hot.done and hot.error is not None and stats["rejected"] == 1
    # an explicit temperature=0 opts back in under a hot engine default
    scfg_hot = ServeConfig(max_batch=1, max_len=32, temperature=0.9)
    eng2 = SpeculativeEngine(cfg, params, scfg_hot, gamma=2)
    cold = Request(uid=1, prompt=prompts[0], max_new=3, temperature=0.0)
    eng2.serve([cold])
    assert cold.done and len(cold.out_tokens) == 3 and cold.error is None


def test_speculative_rejects_unsupported_archs(smoke_model):
    cfg, params, _ = smoke_model
    hybrid = get_config("recurrentgemma-9b", smoke=True)
    with pytest.raises(ValueError, match="decoder-only attention"):
        SpeculativeEngine(hybrid, None, ServeConfig(max_batch=1, max_len=32))
    with pytest.raises(ValueError, match="gamma"):
        SpeculativeEngine(cfg, params, ServeConfig(max_batch=1, max_len=32),
                          gamma=0)


# ---------------------------------------------------------------------------
# Per-request temperature (satellite)
# ---------------------------------------------------------------------------

def test_per_request_temperature_greedy_override(smoke_model):
    """A temperature=0 request inside a hot-default engine must reproduce
    the all-greedy engine's stream for the same prompt (the docstring's
    per-request sampling promise, previously ignored by _sample)."""
    cfg, params, prompts = smoke_model
    greedy_eng = ServingEngine(cfg, params,
                               ServeConfig(max_batch=1, max_len=32))
    ref = Request(uid=0, prompt=prompts[1], max_new=5)
    greedy_eng.serve([ref])
    hot_eng = ServingEngine(cfg, params,
                            ServeConfig(max_batch=2, max_len=32,
                                        temperature=1.5, seed=3))
    cold = Request(uid=1, prompt=prompts[1], max_new=5, temperature=0.0)
    hot = Request(uid=2, prompt=prompts[1], max_new=5)
    hot_eng.serve([cold, hot])
    assert cold.out_tokens == ref.out_tokens
    # the hot request really samples (astronomically unlikely to match
    # greedy on 5 draws over a 256 vocab if temperature were ignored)
    assert hot.out_tokens != ref.out_tokens


def test_speculative_kv_bytes_include_draft_ring(smoke_model):
    """The draft ring is real HBM: every footprint stat must include it
    on top of the baseline engine's target-cache bytes."""
    cfg, params, _ = smoke_model
    scfg = ServeConfig(max_batch=2, max_len=32, kv_format="posit8",
                       kv_layout="paged", page_size=4)
    base = ServingEngine(cfg, params, scfg)
    spec = SpeculativeEngine(cfg, params, scfg, gamma=2)
    draft = spec._draft_kv_bytes()
    assert draft > 0
    assert spec.kv_cache_bytes() == base.kv_cache_bytes() + draft
    assert spec.kv_cache_live_bytes() >= draft
    assert spec.stats["kv_cache_bytes"] == spec.kv_cache_bytes()


def test_per_request_temperature_sampled_path_valid(smoke_model):
    cfg, params, prompts = smoke_model
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=2, max_len=32))
    warm = Request(uid=0, prompt=prompts[0], max_new=6, temperature=0.8)
    eng.serve([warm])
    assert warm.done and len(warm.out_tokens) == 6
    assert all(0 <= t < cfg.vocab for t in warm.out_tokens)
