"""Energy accounting + request-lifecycle observability (PR 8).

Covers the :mod:`repro.obs.energy` model end to end on the smoke model:
by-dtype cost splits summing to their totals, the posit-packed KV
cross-check against ``kv_cache_bytes``, pJ-table determinism, joules
monotonicity, the draft-cheaper-than-target claim, the six-stamp request
lifecycle, queue-wait attribution, SLO counters, the request log, and
the ``scripts/bench_compare.py`` regression gate (synthetic 2x fixture).
"""
import importlib.util
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.hlo_cost import analyze, entry_param_bytes_by_dtype
from repro.models import lm
from repro.obs import EnergyAccountant, Tracer, stage_breakdown
from repro.obs.energy import DRAM_PJ_PER_BYTE, pj_per_mac
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.serve.orchestrator import (Orchestrator, OrchestratorConfig,
                                      StreamingRequest)

MAX_LEN = 64


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n=3, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 13))),
                    max_new=max_new)
            for i in range(n)]


@pytest.fixture(scope="module")
def served_engine(smoke_model):
    """A posit8-KV ring engine that has served a batch (tracer on)."""
    cfg, params = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=MAX_LEN,
                                    kv_format="posit8"),
                        tracer=Tracer(enabled=True))
    stats = eng.serve(_requests(cfg))
    return eng, stats


# ---- hardware-constant pinning (satellite: fallback must not drift) ----

def test_energy_constants_match_hwmodel():
    from benchmarks.hwmodel import TALU
    from benchmarks.hwmodel import DRAM_PJ_PER_BYTE as HW_DRAM
    from benchmarks.hwmodel import pj_per_mac as hw_pj
    assert TALU.pdp_pj == (38.9, 43.44, 46.15)   # paper Table IV
    assert DRAM_PJ_PER_BYTE == HW_DRAM == 20.0
    for bits, want in ((4, 38.9), (8, 38.9), (9, 43.44), (16, 43.44),
                       (17, 46.15), (32, 46.15)):
        assert pj_per_mac(bits) == hw_pj(bits) == want


# ---- hlo_cost by-dtype splits ----

def test_by_dtype_splits_sum_to_totals(served_engine):
    eng, _ = served_engine
    fn, spec = eng.engine.stage_specs["generate"]
    ana = analyze(fn.lower(*spec).compile().as_text())
    assert ana["flops"] > 0 and ana["bytes"] > 0
    assert sum(ana["flops_by_dtype"].values()) == pytest.approx(
        ana["flops"], rel=1e-9)
    assert sum(ana["bytes_by_dtype"].values()) == pytest.approx(
        ana["bytes"], rel=1e-9)
    # MACs are a strict subset of flops, and nonzero for a decode step
    assert 0 < ana["mac_flops"] <= ana["flops"]


def test_posit8_kv_traffic_matches_kv_cache_bytes(served_engine):
    """Satellite (a): the u8 entry-parameter bytes of the decode program
    are exactly the engine's uint8 KV code buffers — the cost model's
    packed-KV traffic attribution agrees with ``kv_cache_bytes``."""
    eng, _ = served_engine
    fn, spec = eng.engine.stage_specs["generate"]
    pb = entry_param_bytes_by_dtype(fn.lower(*spec).compile().as_text())
    cache_u8 = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(dict(eng.cache))
                   if hasattr(l, "dtype") and l.dtype == np.uint8)
    assert cache_u8 > 0, "posit8 KV cache should store u8 codes"
    assert pb.get("u8", 0) == pytest.approx(cache_u8)
    # and those same bytes appear in the engine's KV accounting
    assert cache_u8 <= eng.kv_cache_bytes()


def test_posit16_kv_traffic_is_u16(smoke_model):
    cfg, params = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=1, max_len=MAX_LEN,
                                    kv_format="posit16"))
    eng.serve(_requests(cfg, n=1, max_new=2))
    fn, spec = eng.engine.stage_specs["generate"]
    pb = entry_param_bytes_by_dtype(fn.lower(*spec).compile().as_text())
    cache_u16 = sum(2 * int(np.prod(l.shape))
                    for l in jax.tree_util.tree_leaves(dict(eng.cache))
                    if hasattr(l, "dtype") and l.dtype == np.uint16)
    assert cache_u16 > 0
    assert pb.get("u16", 0) == pytest.approx(cache_u16)


# ---- energy table ----

def test_pj_table_deterministic(served_engine):
    import repro.obs.energy as energy_mod
    eng, _ = served_engine
    t1 = {k: v.as_dict() for k, v in EnergyAccountant(eng).table().items()}
    energy_mod._COST_CACHE.clear()      # force a full re-lower + re-parse
    t2 = {k: v.as_dict() for k, v in EnergyAccountant(eng).table().items()}
    assert t1 == t2
    assert set(t1) == {"prefill", "insert", "generate"}
    for e in t1.values():
        assert e["pj_per_call"] >= 0


def test_joules_monotone_in_tokens(smoke_model):
    cfg, params = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=MAX_LEN,
                                    kv_format="posit8"))
    acct = EnergyAccountant(eng)
    eng.serve(_requests(cfg, n=2, max_new=4))
    b1 = acct.breakdown()
    eng.serve(_requests(cfg, n=2, max_new=8, seed=1))
    b2 = acct.breakdown()
    assert b2["joules_total"] > b1["joules_total"] > 0
    assert b2["tokens"] > b1["tokens"]
    assert b1["joules_per_token"] > 0
    # cumulative breakdowns publish registry gauges
    g = eng.metrics.snapshot()["gauges"]
    assert g["energy.joules_total"] == pytest.approx(b2["joules_total"])
    assert g["energy.joules_per_token"] == pytest.approx(
        b2["joules_per_token"])
    # windowed: the second serve's calls delta prices the window only
    delta = acct.calls_delta(acct.calls_snapshot(), {})
    win = acct.breakdown(calls=delta, tokens=b2["tokens"])
    assert win["joules_total"] == pytest.approx(b2["joules_total"])


def test_draft_step_cheaper_than_target_step(smoke_model, served_engine):
    """The speculative premise in energy terms: a posit8-weight draft
    decode step must price below a target-precision decode step."""
    from repro.serve.speculative import SpeculativeEngine
    cfg, params = smoke_model
    base_eng, _ = served_engine
    spec = SpeculativeEngine(cfg, params,
                             ServeConfig(max_batch=2, max_len=MAX_LEN,
                                         kv_format="posit8"), gamma=2)
    spec.serve(_requests(cfg))
    st = EnergyAccountant(spec).table()
    bt = EnergyAccountant(base_eng).table()
    d, t = st["draft.generate"], bt["generate"]
    assert d.pj_total < t.pj_total
    assert d.pj_compute < t.pj_compute     # 8-bit MACs < 16/32-bit MACs
    assert d.pj_memory < t.pj_memory       # packed weights fetch fewer B
    # the draft stage's MAC mix is dominated by the 8-bit format
    mix = d.mac_mix
    assert max(mix.values(), key=lambda v: v["frac"])["bits"] == 8


# ---- request lifecycle / SLO / request log ----

def test_lifecycle_spans_slo_and_request_log(smoke_model, tmp_path):
    cfg, params = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=1, max_len=MAX_LEN,
                                    kv_format="posit8"),
                        tracer=Tracer(enabled=True))
    logp = tmp_path / "requests.jsonl"
    ocfg = OrchestratorConfig(detokenize=False, ttft_slo_s=0.0,
                              itl_slo_s=1e3, request_log=str(logp))
    rng = np.random.default_rng(0)
    with Orchestrator(eng, ocfg) as orch:
        sreqs = [StreamingRequest(
            rng.integers(1, cfg.vocab, 6).tolist(), max_new=4)
            for _ in range(3)]
        # one never-admissible request: rejects also land in the log
        sreqs.append(StreamingRequest(list(range(MAX_LEN + 8)),
                                      max_new=4))
        for s in sreqs:
            assert orch.submit(s)
        for s in sreqs:
            assert s.wait(120.0)
    # six stamps, strictly ordered, on every finished request
    for s in sreqs[:3]:
        lc = s.lifecycle()
        assert list(lc) == ["submit", "admit", "prefill_done",
                            "insert_done", "first_token", "finish"]
        vals = list(lc.values())
        assert all(b >= a for a, b in zip(vals, vals[1:]))
    # rejected: terminal stamps only
    rej = sreqs[3].lifecycle()
    assert sreqs[3].error is not None
    assert list(rej) == ["submit", "finish"]
    # SLO: ttft_slo_s=0 -> every finished request violates; itl huge -> 0
    c = eng.metrics.snapshot()["counters"]
    assert c["orch.slo.ttft_total"] == 3
    assert c["orch.slo.ttft_violations"] == 3
    assert c["orch.slo.itl_total"] > 0
    assert c["orch.slo.itl_violations"] == 0
    # request log: one valid JSON line per terminal request
    lines = [json.loads(l) for l in logp.read_text().splitlines()]
    assert len(lines) == 4
    by_err = [l for l in lines if l["error"]]
    assert len(by_err) == 1
    for l in lines:
        assert "lifecycle" in l and "deltas" in l
        if not l["error"]:
            assert l["ttft_s"] > 0
            assert l["deltas"]["total_s"] >= l["deltas"]["ttft_s"]
    # queue-wait bucket reproduces the per-request admit-submit stamps
    bd = stage_breakdown(eng.tracer, 1.0)
    stamp_wait = sum(s.lifecycle_deltas().get("queue_wait_s", 0.0)
                     for s in sreqs[:3])
    trace_wait = bd["queue"].get("queue.wait", {}).get("total_s", 0.0)
    assert trace_wait == pytest.approx(stamp_wait, rel=1e-3, abs=1e-6)
    assert bd["queue"].get("queue.wait", {}).get("count", 0) == 3


# ---- bench_compare regression gate ----

def _load_bench_compare():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                        "bench_compare.py")
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_compare_gates_synthetic_regression(tmp_path):
    bc = _load_bench_compare()
    results = tmp_path / "results"
    baselines = tmp_path / "baselines"
    results.mkdir()
    good = {"loads": [{"load_factor": 1.0, "tok_per_s": 100.0,
                       "ttft_ms": {"p99": 10.0}, "itl_ms": {"p99": 5.0}}],
            "energy_breakdown": {"joules_per_token": 1e-4}}
    (results / "BENCH_serving.json").write_text(json.dumps(good))
    argv = ["serving", "--results-dir", str(results),
            "--baseline-dir", str(baselines)]
    assert bc.main(argv + ["--update"]) == 0
    assert (baselines / "BENCH_serving.json").exists()
    # unchanged results pass
    assert bc.main(argv) == 0
    # 2x modeled joules/token: deterministic metric, tight gate -> fail
    bad = json.loads(json.dumps(good))
    bad["energy_breakdown"]["joules_per_token"] = 2e-4
    (results / "BENCH_serving.json").write_text(json.dumps(bad))
    assert bc.main(argv) == 1
    # 2x wall-clock slowdown stays inside the loose (3x) CI-noise gate,
    # 4x does not
    bad = json.loads(json.dumps(good))
    bad["loads"][0]["tok_per_s"] = 50.0
    (results / "BENCH_serving.json").write_text(json.dumps(bad))
    assert bc.main(argv) == 0
    bad["loads"][0]["tok_per_s"] = 24.0
    (results / "BENCH_serving.json").write_text(json.dumps(bad))
    assert bc.main(argv) == 1
    # missing baseline warns + passes (first run must not gate)
    (baselines / "BENCH_serving.json").unlink()
    assert bc.main(argv) == 0
