"""Vendored property-testing fallback for the ``hypothesis`` API surface
this suite uses (``given`` / ``settings`` / ``strategies``).

CI for this repo runs offline, so ``pip install hypothesis`` is not an
option; the four property-based test modules import hypothesis when it is
available and fall back to this shim otherwise.  The shim keeps every
property *being checked* intact — it only swaps hypothesis's adaptive
search for N deterministic draws from a seeded ``numpy`` generator (seed
derived from the test's qualified name, so failures reproduce run-to-run
and example counts honour ``settings(max_examples=...)``).

Supported strategies: ``st.integers(lo, hi)``, ``st.floats(min, max,
allow_nan=..., width=...)``, ``st.sampled_from(seq)``.  ``floats`` draws
log-uniform magnitudes (plus signed endpoints and exact zero) rather than
uniform reals, matching how hypothesis probes float edge cases across the
exponent range.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def _floats(min_value=None, max_value=None, allow_nan=False,
            allow_infinity=False, width=64):
    lo = -1e308 if min_value is None else float(min_value)
    hi = 1e308 if max_value is None else float(max_value)
    cast = np.float32 if width == 32 else np.float64
    maxmag = max(abs(lo), abs(hi), 1e-30)

    def draw(rng):
        u = rng.random()
        if u < 0.05:
            v = lo
        elif u < 0.10:
            v = hi
        elif u < 0.15 and lo <= 0.0 <= hi:
            v = 0.0
        else:
            # log-uniform magnitude across the full exponent range
            lo_e = -126.0 if width == 32 else -300.0
            hi_e = float(np.log2(maxmag))
            mag = 2.0 ** rng.uniform(lo_e, hi_e)
            sign = -1.0 if (rng.random() < 0.5 and lo < 0) else 1.0
            v = float(np.clip(sign * mag, lo, hi))
        return float(cast(v))

    return _Strategy(draw)


strategies = types.SimpleNamespace(integers=_integers, floats=_floats,
                                   sampled_from=_sampled_from)

_DEFAULT_MAX_EXAMPLES = 50


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Decorator recording the example budget on the ``given`` wrapper."""

    def deco(fn):
        fn._pc_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the wrapped test on N deterministic seeded draws."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_pc_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode("utf-8")))
            for i in range(n):
                extra = [s.draw(rng) for s in arg_strategies]
                kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *extra, **kwargs, **kw)
                except Exception:
                    print(f"Falsifying example (draw {i}/{n}): "
                          f"args={extra!r} kwargs={kw!r}")
                    raise

        # hide the drawn parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
