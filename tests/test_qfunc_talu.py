"""Q-function semantics (Tables I/II) + TALU cycle simulator (Table III)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # offline CI: vendored deterministic fallback
    from _propcheck import given, settings, strategies as st

from repro.core import posit_ref, qfunc
from repro.core.formats import POSIT8_0, POSIT8_2, POSIT16_2
from repro.core.talu import TABLE3, TALU, VectorUnit


# ---------------------------------------------------------------------------
# Table I / II rows
# ---------------------------------------------------------------------------

BYTES = np.arange(256)


def test_q_logic_ops_exhaustive():
    a = np.repeat(BYTES, 256)
    b = np.tile(BYTES, 256)
    for i in range(8):
        np.testing.assert_array_equal(qfunc.q_and(a, b, i), (a >> i) & (b >> i) & 1)
        np.testing.assert_array_equal(qfunc.q_or(a, b, i), ((a >> i) | (b >> i)) & 1)
        np.testing.assert_array_equal(qfunc.q_not(b, i), 1 - ((b >> i) & 1))
        m = (1 << (i + 1)) - 1
        np.testing.assert_array_equal(qfunc.q_comp(a, b, i), ((a & m) >= (b & m)).astype(int))


def test_q_add_planes_exhaustive():
    """ADD = carry plane (Table I) then sum plane (Table II): the paper's key
    claim that both CLA carries and sums are threshold functions."""
    a = np.repeat(BYTES, 256)
    b = np.tile(BYTES, 256)
    for c0 in (0, 1):
        s, cout = qfunc.cluster_add(a, b, p=8, c0=c0)
        np.testing.assert_array_equal(s, (a + b + c0) & 0xFF)
        np.testing.assert_array_equal(cout, (a + b + c0) >> 8)


def test_q_xor_two_step_exhaustive():
    a = np.repeat(BYTES, 256)
    b = np.tile(BYTES, 256)
    np.testing.assert_array_equal(qfunc.cluster_xor(a, b, p=8), a ^ b)


def test_q_posit_decode_row():
    """Table I posit-decode row: V_i thermometer for the paper's example."""
    t_val = 0b1110100  # P(8,2) = 01110100, body
    v = [int(qfunc.q_posit_decode_compare(t_val, i, p=8)) for i in range(7)]
    assert sum(v) == 3  # regime run length -> K = 2
    assert v == [0, 0, 0, 0, 1, 1, 1]  # V_0..V_6 (thermometer)


# ---------------------------------------------------------------------------
# TALU programs: bit accuracy
# ---------------------------------------------------------------------------

def test_talu_int_mul_accurate():
    t = TALU()
    rng = np.random.default_rng(0)
    for bits in (4, 8, 16):
        for _ in range(20):
            a = int(rng.integers(0, 1 << bits))
            b = int(rng.integers(0, 1 << bits))
            assert t.int_mul(a, b, bits=bits) == a * b


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255))
def test_talu_posit_ops_match_oracle(a, b):
    t = TALU()
    fmt = POSIT8_2
    got_m = t.posit_mul(a, b, fmt)
    got_a = t.posit_add(a, b, fmt)
    assert got_m == posit_ref.mul(a, b, 8, 2)
    assert got_a == posit_ref.add(a, b, 8, 2)


# ---------------------------------------------------------------------------
# Cycle counts (Table III)
# ---------------------------------------------------------------------------

def test_decode_cycles_exact():
    t = TALU()
    assert t.measure("posit_decode", POSIT8_0) == 2
    assert t.measure("posit_decode", POSIT8_2) == 2
    assert t.measure("posit_decode", POSIT16_2) == 6


def test_int_cycles_exact():
    t = TALU()
    assert t.measure("int_add", bits=4) == 2      # Table III: INT4 add = 2
    assert t.measure("int_add", bits=8) == 2      # INT8 add = 2
    assert t.measure("int_add", bits=16) == 4     # INT16 add = 4


def test_table3_reproduced_exactly():
    """The reconstructed micro-op programs land every Table III cell."""
    from repro.core.formats import POSIT16_0
    t = TALU()
    cells = [
        ("P(8,0)", "posit_decode", POSIT8_0, None, "decode"),
        ("P(8,2)", "posit_decode", POSIT8_2, None, "decode"),
        ("P(16,0)", "posit_decode", POSIT16_0, None, "decode"),
        ("P(16,2)", "posit_decode", POSIT16_2, None, "decode"),
        ("P(8,0)", "posit_mul", POSIT8_0, None, "mul"),
        ("P(8,2)", "posit_mul", POSIT8_2, None, "mul"),
        ("P(16,0)", "posit_mul", POSIT16_0, None, "mul"),
        ("P(16,2)", "posit_mul", POSIT16_2, None, "mul"),
        ("P(8,0)", "posit_add", POSIT8_0, None, "add"),
        ("P(8,2)", "posit_add", POSIT8_2, None, "add"),
        ("P(16,0)", "posit_add", POSIT16_0, None, "add"),
        ("P(16,2)", "posit_add", POSIT16_2, None, "add"),
        ("INT4", "int_mul", None, 4, "mul"),
        ("INT8", "int_mul", None, 8, "mul"),
        ("INT16", "int_mul", None, 16, "mul"),
        ("INT4", "int_add", None, 4, "add"),
        ("INT8", "int_add", None, 8, "add"),
        ("INT16", "int_add", None, 16, "add"),
        ("FP8", "fp_mul", None, 8, "mul"),
        ("FP16", "fp_mul", None, 16, "mul"),
        ("FP8", "fp_add", None, 8, "add"),
        ("FP16", "fp_add", None, 16, "add"),
    ]
    for cfg, kind, fmt, bits, op in cells:
        ours = t.measure(kind, fmt=fmt, bits=bits or 8)
        assert ours == TABLE3[(cfg, op)], (cfg, op, ours, TABLE3[(cfg, op)])


def test_vector_unit_lockstep():
    v = VectorUnit()
    # one wave: 128 elements at 19 cycles each op
    assert v.vector_op_cycles(19, 128) == 19
    assert v.vector_op_cycles(19, 129) == 38
    # 3x3 matmul = 27 MACs -> one wave of muls + one wave of adds
    assert v.matmul_cycles(3, 3, 3, 19, 23) == 19 + 23
