"""Flash (blockwise, custom-VJP) attention vs the dense reference:
forward AND gradients, across GQA ratios / causal / sliding-window /
padded (non-divisible) shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blockwise_attention, dense_attention

CASES = [
    # (b, s, skv, nh, nkv, hd, causal, window, qb, kvb)
    (2, 64, 64, 4, 4, 16, True, None, 16, 32),
    (2, 64, 64, 4, 2, 16, True, None, 16, 16),     # GQA 2x
    (1, 48, 48, 8, 1, 8, True, None, 16, 16),      # MQA
    (2, 64, 64, 4, 2, 16, False, None, 32, 32),    # bidirectional
    (2, 64, 64, 4, 4, 16, True, 24, 16, 16),       # sliding window
    (1, 50, 50, 2, 2, 16, True, None, 16, 16),     # non-divisible -> pad
    (1, 32, 80, 4, 2, 16, False, None, 16, 16),    # cross (skv != s)
]


def _mk(b, s, skv, nh, nkv, hd, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, nh, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, nkv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, nkv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("case", CASES, ids=str)
def test_forward_matches_dense(case):
    b, s, skv, nh, nkv, hd, causal, window, qb, kvb = case
    q, k, v = _mk(b, s, skv, nh, nkv, hd)
    got = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_block=qb, kv_block=kvb)
    want = dense_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES, ids=str)
def test_grads_match_dense(case):
    b, s, skv, nh, nkv, hd, causal, window, qb, kvb = case
    q, k, v = _mk(b, s, skv, nh, nkv, hd, seed=1)
    w = jnp.asarray(np.random.default_rng(2).standard_normal(
        (b, s, nh, hd)), jnp.float32)

    def loss_flash(q, k, v):
        o = blockwise_attention(q, k, v, causal=causal, window=window,
                                q_block=qb, kv_block=kvb)
        return jnp.sum(o * w)

    def loss_dense(q, k, v):
        return jnp.sum(dense_attention(q, k, v, causal=causal,
                                       window=window) * w)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b_, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_bwd_saves_no_quadratic_residual():
    """The custom VJP must not stack (qb x kvb) probability tiles: check
    the jaxpr of grad for any saved f32 tensor with both seq dims."""
    b, s, nh, hd = 1, 256, 2, 8
    q, k, v = _mk(b, s, s, nh, nh, hd)

    def loss(q, k, v):
        return jnp.sum(blockwise_attention(q, k, v, q_block=32,
                                           kv_block=32))

    jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
    # residual tensors appear as constvars/outvars between fwd and bwd;
    # scan residual stacking would show a (8, ..., 32, 32, ...) or larger
    # (nq, nk)-shaped buffer.  Look for any var with >= s*s elements
    # besides the inputs themselves.
    big = []
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            sh = getattr(var.aval, "shape", ())
            n = int(np.prod(sh)) if sh else 0
            if n >= s * s * nh:   # 128k f32 = a full score matrix
                big.append(sh)
    assert not big, f"quadratic residuals found: {big}"


def test_bf16_stability():
    q, k, v = _mk(2, 128, 128, 4, 2, 32, seed=3, dtype=jnp.bfloat16)
    out = blockwise_attention(q, k, v, q_block=32, kv_block=64)
    want = dense_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32))
    assert np.isfinite(np.asarray(out, np.float32)).all()
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=0.1, atol=0.1)
