"""End-to-end system tests: training convergence, fault tolerance
(checkpoint/restart exactness, crash recovery), TC-policy training, and
the serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.transprecision import PAPER_EDGE, TCPolicy
from repro.data.pipeline import make_pipeline
from repro.models import lm
from repro.optim import AdamWConfig
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.train import Trainer, TrainerConfig
from repro.train.fault_tolerance import CrashBarrier, ElasticPlan, \
    HeartbeatMonitor


def tiny_cfg():
    return get_config("paper-edge", smoke=True)


def test_training_loss_decreases():
    """The synthetic stream has learnable structure; loss must fall."""
    cfg = tiny_cfg()
    tr = Trainer(cfg, TrainerConfig(steps=30, global_batch=8, seq_len=64,
                                    log_every=10),
                 AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=3))
    out = tr.run()
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    assert last < first - 0.3, (first, last)


def test_checkpoint_restart_exact(tmp_path):
    """Train 6 steps straight vs 3 steps + crash + restore + 3 steps:
    the final losses must agree (deterministic pipeline + exact restore)."""
    cfg = tiny_cfg()
    opt = AdamWConfig(lr=1e-3, total_steps=6, warmup_steps=1)

    tr1 = Trainer(cfg, TrainerConfig(steps=6, global_batch=4, seq_len=32,
                                     log_every=1), opt)
    out1 = tr1.run()

    ckpt = str(tmp_path / "ck")
    tcfg = TrainerConfig(steps=6, global_batch=4, seq_len=32,
                         checkpoint_dir=ckpt, checkpoint_every=3,
                         async_checkpoint=False, log_every=1)
    tr2 = Trainer(cfg, tcfg, opt,
                  crash_barrier=CrashBarrier(crash_at_steps=[4]))
    with pytest.raises(CrashBarrier.SimulatedFault):
        tr2.run()
    assert tr2.ckpt.latest_step() == 3
    tr3 = Trainer(cfg, tcfg, opt)   # fresh process-equivalent; restores
    out3 = tr3.run()
    np.testing.assert_allclose(out3["metrics"]["loss"],
                               out1["metrics"]["loss"], rtol=1e-5)


def test_async_checkpoint_and_keep_k(tmp_path):
    cfg = tiny_cfg()
    tcfg = TrainerConfig(steps=9, global_batch=2, seq_len=16,
                         checkpoint_dir=str(tmp_path / "ck"),
                         checkpoint_every=2, checkpoint_keep=2,
                         async_checkpoint=True, log_every=100)
    tr = Trainer(cfg, tcfg, AdamWConfig(total_steps=9, warmup_steps=1))
    tr.run()
    tr.ckpt.wait()
    steps = tr.ckpt.steps()
    assert steps[-1] == 9
    assert len(steps) <= 2 + 1   # keep-k plus the final blocking save


def test_tc_policy_training_converges():
    """Training THROUGH the paper's P(8,2) policy (STE fake-quant) learns."""
    cfg = tiny_cfg()
    tr = Trainer(cfg, TrainerConfig(steps=30, global_batch=8, seq_len=64,
                                    log_every=10),
                 AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=3),
                 policy=PAPER_EDGE)
    out = tr.run()
    assert out["history"][-1]["loss"] < out["history"][0]["loss"] - 0.2


def test_grad_wire_compression_matches_uncompressed_direction():
    """posit16 wire + error feedback must track the uncompressed run
    closely over a few steps (EF keeps compression unbiased over time)."""
    cfg = tiny_cfg()
    pol = TCPolicy(name="wire", grad_wire="posit16_2")
    t_plain = Trainer(cfg, TrainerConfig(steps=8, global_batch=4, seq_len=32,
                                         log_every=1),
                      AdamWConfig(lr=1e-3, total_steps=8, warmup_steps=1))
    t_wire = Trainer(cfg, TrainerConfig(steps=8, global_batch=4, seq_len=32,
                                        log_every=1),
                     AdamWConfig(lr=1e-3, total_steps=8, warmup_steps=1),
                     policy=pol)
    o1, o2 = t_plain.run(), t_wire.run()
    assert abs(o1["metrics"]["loss"] - o2["metrics"]["loss"]) < 0.15


def test_serving_engine_continuous_batching():
    cfg = tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=64),
                        policy=PAPER_EDGE)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5), max_new=6)
            for i in range(5)]   # 5 requests through 2 slots
    stats = eng.serve(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)
    assert stats["prefills"] == 5


def test_serving_matches_forward_greedy():
    """Engine greedy decode == argmax of the training-path forward.
    f32 model: random-init bf16 logits are near-flat, so bf16 rounding
    differences between paths flip argmax ties spuriously."""
    import dataclasses
    cfg = dataclasses.replace(tiny_cfg(), dtype_name="float32")
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    prompt = np.arange(1, 9) % cfg.vocab
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=1, max_len=64))
    req = Request(uid=0, prompt=prompt, max_new=4)
    eng.serve([req])
    # reference: iterative full forward
    toks = list(prompt)
    want = []
    for _ in range(4):
        logits, _ = lm.forward(params,
                               {"tokens": jnp.asarray([toks], jnp.int32)},
                               cfg)
        nxt = int(np.asarray(logits[0, -1, :cfg.vocab]).argmax())
        want.append(nxt)
        toks.append(nxt)
    assert req.out_tokens == want


def test_heartbeat_and_elastic_plan():
    mon = HeartbeatMonitor(n_hosts=4, dead_timeout_s=10, window=8)
    now = 1000.0
    for h in range(4):
        for s in range(8):
            mon.beat(h, s, 1.0 if h != 3 else 5.0, now=now)
    assert mon.stragglers() == [3]
    mon.beat(0, 9, 1.0, now=now + 100)
    dead = mon.dead_hosts(now=now + 100)
    assert set(dead) == {1, 2, 3}
    plan = ElasticPlan(global_batch=16, n_hosts=4)
    shards4 = [plan.shard_for(h) for h in range(4)]
    assert shards4[0] == slice(0, 4)
    plan2 = plan.resize(2)
    assert plan2.shard_for(1) == slice(8, 16)
    with pytest.raises(ValueError):
        ElasticPlan(global_batch=10, n_hosts=4)


def test_elastic_data_resharding_is_lossless():
    """Same step, different world sizes: union of host batches == global."""
    cfg = tiny_cfg()
    pipe = make_pipeline(cfg, global_batch=8, seq_len=16, seed=3)
    full = pipe.global_batch(step=5)["tokens"]
    for n_hosts in (1, 2, 4, 8):
        parts = [pipe.host_batch(5, h, n_hosts)["tokens"]
                 for h in range(n_hosts)]
        np.testing.assert_array_equal(np.concatenate(parts), full)
