"""Golden-stream tests for the disaggregated three-stage engine API.

The refactor contract: driving ``TransprecisionEngine.prefill`` →
``insert`` → ``generate`` by hand emits token-for-token the stream the
``ServingEngine`` driver (and, for f32, a full-context ``lm.forward``
argmax loop) produces — on both KV layouts and across storage formats —
and the paged prefix never materialises an intermediate max_len ring
cache (the bucket-width Prefix is scattered straight into pool pages).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.serve.engine_api import TransprecisionEngine, rollback_ring_cache
from repro.serve.paged import PageAllocator, SlotPages, pages_for
from repro.serve.speculative import SpeculativeEngine

MAX_BATCH, MAX_LEN, PAGE_SIZE, MAX_NEW = 3, 64, 8, 8
FORMATS = ("f32", "posit16", "posit8")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n) for n in (4, 11, 7)]
    return cfg, params, prompts


def _scfg(layout, fmt):
    return ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN, kv_format=fmt,
                       kv_layout=layout,
                       page_size=PAGE_SIZE if layout == "paged" else None)


def _serve_ref(cfg, params, scfg, prompts, max_new=MAX_NEW):
    eng = ServingEngine(cfg, params, scfg)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=max_new)
            for i, p in enumerate(prompts)]
    eng.serve(reqs)
    return eng, [list(r.out_tokens) for r in reqs]


def _bucketed_prefix(engine, params, prompts):
    lens = [len(p) for p in prompts]
    bucket = engine.bucket_for(max(lens))
    toks = np.zeros((len(prompts), bucket), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    return engine.prefill(params, toks, lens), bucket


def _raw_decode_loop(engine, params, state, prefix_logits, max_new):
    """First token from the prefix logits, the rest from generate ticks."""
    vocab = engine.cfg.vocab
    streams = [[int(t)] for t in
               np.asarray(prefix_logits)[:, :vocab].argmax(-1)]
    state["tok"] = jax.numpy.asarray(
        np.asarray([[s[0]] for s in streams], np.int32))
    for _ in range(max_new - 1):
        state, logits = engine.generate(params, state)
        for i, t in enumerate(np.asarray(logits)[:, :vocab].argmax(-1)):
            streams[i].append(int(t))
    return state, streams


@pytest.mark.parametrize("fmt", FORMATS)
def test_raw_api_golden_stream_ring(smoke_model, fmt):
    cfg, params, prompts = smoke_model
    eng_ref, ref = _serve_ref(cfg, params, _scfg("ring", fmt), prompts)

    engine = TransprecisionEngine(cfg, eng_ref.policy, MAX_BATCH, MAX_LEN)
    state = engine.init_decode_state()
    prefix, _ = _bucketed_prefix(engine, params, prompts)
    for slot in range(len(prompts)):
        state = engine.insert(prefix, state, slot, row=slot)
    _, streams = _raw_decode_loop(engine, params, state, prefix["logits"],
                                  MAX_NEW)
    assert streams == ref, f"raw ring API diverged from driver ({fmt})"

    if fmt == "f32":   # anchor to the model itself, not just the driver
        for p, s in zip(prompts, ref):
            ctx = list(map(int, p))
            for tok in s:
                logits, _ = lm.forward(
                    params, {"tokens": np.asarray([ctx], np.int32)}, cfg)
                nxt = np.asarray(logits)[0, len(ctx) - 1, : cfg.vocab]
                assert int(np.argmax(nxt)) == tok
                ctx.append(tok)


@pytest.mark.parametrize("fmt", FORMATS)
def test_raw_api_golden_stream_paged(smoke_model, fmt):
    cfg, params, prompts = smoke_model
    eng_ref, ref = _serve_ref(cfg, params, _scfg("paged", fmt), prompts)

    engine = TransprecisionEngine(cfg, eng_ref.policy, MAX_BATCH, MAX_LEN,
                                  num_pages=eng_ref.num_pages)
    state = engine.init_decode_state()
    alloc = PageAllocator(eng_ref.num_pages, PAGE_SIZE)
    pmax = pages_for(MAX_LEN, PAGE_SIZE)
    table = np.zeros((MAX_BATCH, pmax), np.int64)
    prefix, bucket = _bucketed_prefix(engine, params, prompts)
    for slot, p in enumerate(prompts):
        n = len(p)
        # preallocate the whole stream so the table is static in the loop
        pages = alloc.alloc(pages_for(n + MAX_NEW + 1, PAGE_SIZE))
        table[slot] = SlotPages(PAGE_SIZE, pages).table_row(pmax)
        dst = np.zeros(bucket, np.int64)      # bucket pad -> trash row 0
        t = np.arange(n)
        dst[:n] = np.asarray(pages)[t // PAGE_SIZE] * PAGE_SIZE \
            + t % PAGE_SIZE
        state["page_table"] = jax.numpy.asarray(table)
        state = engine.insert(prefix, state, slot, row=slot, dst_rows=dst)
    _, streams = _raw_decode_loop(engine, params, state, prefix["logits"],
                                  MAX_NEW)
    assert streams == ref, f"raw paged API diverged from driver ({fmt})"


@pytest.mark.parametrize("fmt", FORMATS)
def test_ring_and_paged_streams_identical(smoke_model, fmt):
    cfg, params, prompts = smoke_model
    _, ring = _serve_ref(cfg, params, _scfg("ring", fmt), prompts)
    _, paged = _serve_ref(cfg, params, _scfg("paged", fmt), prompts)
    assert ring == paged


def test_paged_prefix_is_bucket_width_not_max_len(smoke_model):
    """Acceptance: paged prefill never allocates the old intermediate
    max_len ring cache — every prefix K/V leaf is bucket-wide."""
    cfg, params, prompts = smoke_model
    eng = ServingEngine(cfg, params, _scfg("paged", "posit8"))
    engine = eng.engine
    prefix, bucket = _bucketed_prefix(engine, params, prompts)
    assert bucket < MAX_LEN
    for blk in prefix["cache"]["blocks"]:
        for name in ("k", "v", "k_scale", "v_scale"):
            assert blk[name].shape[2] == bucket, (
                f"{name} prefix rows widened to {blk[name].shape[2]} "
                f"(bucket {bucket}, max_len {MAX_LEN})")


def test_bucketed_prefill_bit_identical_to_exact(smoke_model):
    cfg, params, prompts = smoke_model
    engine = TransprecisionEngine(
        cfg, ServingEngine(cfg, params,
                           _scfg("ring", "posit8")).policy,
        MAX_BATCH, MAX_LEN)
    p = prompts[1]
    n = len(p)
    prefix, bucket = _bucketed_prefix(engine, params, [p] * MAX_BATCH)
    exact = engine.prefill(params, np.asarray([p] * MAX_BATCH, np.int32))
    np.testing.assert_array_equal(np.asarray(prefix["logits"]),
                                  np.asarray(exact["logits"]))
    for pb, eb in zip(prefix["cache"]["blocks"],
                      exact["cache"]["blocks"]):
        for name in ("k", "v", "k_scale", "v_scale"):
            np.testing.assert_array_equal(
                np.asarray(pb[name])[:, :, :n],
                np.asarray(eb[name])[:, :, :n], err_msg=name)


def test_rollback_ring_scatter_matches_brute_force(smoke_model):
    """The O(B·t) scatter rollback equals a brute-force 'reset rows
    [scrub_from, window_end) to init' reference on every scrubbed leaf."""
    cfg, params, prompts = smoke_model
    eng = ServingEngine(cfg, params, _scfg("ring", "posit8"))
    engine = eng.engine
    state = engine.init_decode_state()
    prefix, _ = _bucketed_prefix(engine, params, prompts)
    for slot in range(len(prompts)):
        state = engine.insert(prefix, state, slot, row=slot)
    state, _ = _raw_decode_loop(engine, params, state, prefix["logits"], 4)

    t = 3
    pos = np.asarray(state["pos"])                     # everyone advanced
    window_end = pos.copy()
    scrub_from = np.array([pos[0] - 2, pos[1], pos[2] - 3])  # slot1 no-op
    new_pos = scrub_from.copy()
    rolled = rollback_ring_cache(state, new_pos, window_end, scrub_from, t)

    np.testing.assert_array_equal(np.asarray(rolled["pos"]), new_pos)
    for bi, (old, new) in enumerate(zip(state["blocks"],
                                        rolled["blocks"])):
        for name in ("k", "v", "k_scale", "v_scale"):
            want = np.asarray(old[name]).copy()        # (P, B, W, ...)
            init = 1.0 if name.endswith("_scale") else 0
            for s in range(MAX_BATCH):
                want[:, s, scrub_from[s]:window_end[s]] = init
            np.testing.assert_array_equal(np.asarray(new[name]), want,
                                          err_msg=f"block{bi}.{name}")


@pytest.mark.parametrize("layout", ["ring", "paged"])
def test_cap_truncated_speculative_identical_to_baseline(smoke_model,
                                                         layout):
    """Dynamic chunk shrink: speculative slots decode all the way to
    max_len - 1, so cap-truncated streams match baseline exactly."""
    cfg, params, prompts = smoke_model
    scfg = ServeConfig(max_batch=2, max_len=24, kv_format="posit8",
                       kv_layout=layout,
                       page_size=PAGE_SIZE if layout == "paged" else None)
    _, ref = _serve_ref(cfg, params, scfg, prompts, max_new=64)
    # cap-truncated: the slot frees at pos == max_len - 1, and the final
    # emitted token never enters the cache, so prompt + stream == max_len
    assert all(len(p) + len(s) == scfg.max_len
               for p, s in zip(prompts, ref)), "cap never hit; bad shapes"
    spec = SpeculativeEngine(cfg, params, scfg, gamma=4)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=64)
            for i, p in enumerate(prompts)]
    spec.serve(reqs)
    assert [list(r.out_tokens) for r in reqs] == ref


def test_page_overcommit_evicts_and_recovers(smoke_model):
    """Pool-dry graceful degradation: with the worst-case reservation
    waived, a dried pool evicts the newest sequence (requeued for
    recompute-on-readmit) instead of raising, and every stream still
    matches the amply-pooled run."""
    cfg, params, prompts = smoke_model
    full = ServeConfig(max_batch=2, max_len=MAX_LEN, kv_format="posit8",
                       kv_layout="paged", page_size=8)
    _, ref = _serve_ref(cfg, params, full, prompts, max_new=10)

    # 4 usable pages: both prompts admit on current demand (1 + 2 pages)
    # but their combined growth needs 5, so the pool must dry mid-decode
    tight = dataclasses.replace(full, num_pages=5, page_overcommit=True)
    eng = ServingEngine(cfg, params, tight)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=10)
            for i, p in enumerate(prompts)]
    stats = eng.serve(reqs)
    assert stats["evictions"] >= 1, "pool never dried; shrink num_pages"
    assert all(r.done and r.error is None for r in reqs)
    assert [list(r.out_tokens) for r in reqs] == ref

    # without overcommit the same pool admits one sequence at a time
    # (worst-case reservation) and never needs an eviction
    strict = dataclasses.replace(tight, page_overcommit=False)
    eng2 = ServingEngine(cfg, params, strict)
    reqs2 = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=10)
             for i, p in enumerate(prompts)]
    stats2 = eng2.serve(reqs2)
    assert stats2["evictions"] == 0
    assert [list(r.out_tokens) for r in reqs2] == ref
