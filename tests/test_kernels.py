"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps per kernel; decode/encode demand bit-exactness, matmul
allows accumulation-order tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # offline CI: vendored deterministic fallback
    from _propcheck import given, settings, strategies as st

from repro.core import posit, quant
from repro.core.formats import POSIT8_0, POSIT8_2, POSIT16_1, POSIT16_2, PositFormat
from repro.kernels import ref
from repro.kernels.ops import posit_decode, posit_encode, posit_matmul, qt_matmul

FMTS = [POSIT8_0, POSIT8_2, POSIT16_1, POSIT16_2]
SHAPES = [(8, 16), (33, 65), (128, 128), (200, 72)]


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_decode_kernel_bit_exact(fmt, shape):
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 1 << fmt.bits, shape).astype(fmt.np_storage_dtype)
    got = posit_decode(codes, fmt, block=(32, 32), interpret=True)
    want = ref.posit_decode_ref(codes, fmt)
    nn = ~np.isnan(np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got)[nn], np.asarray(want)[nn])
    assert np.all(np.isnan(np.asarray(got)[~nn]))


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("shape", [(16, 16), (40, 100)], ids=str)
@pytest.mark.parametrize("dist", ["normal", "tiny", "huge"])
def test_encode_kernel_bit_exact(fmt, shape, dist):
    rng = np.random.default_rng(1)
    scale = {"normal": 1.0, "tiny": 1e-8, "huge": 1e8}[dist]
    x = (rng.normal(0, scale, shape)).astype(np.float32)
    got = posit_encode(x, fmt, block=(32, 32), interpret=True)
    want = ref.posit_encode_ref(x, fmt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_encode_decode_kernel_roundtrip():
    fmt = POSIT8_2
    codes = np.arange(256, dtype=np.uint8).reshape(16, 16)
    vals = posit_decode(codes, fmt, interpret=True)
    vals = jnp.nan_to_num(vals)  # NaR slot
    back = posit_encode(vals, fmt, interpret=True)
    expect = codes.copy().ravel()
    expect[128] = 0  # NaR -> nan_to_num(0) -> 0
    np.testing.assert_array_equal(np.asarray(back).ravel(), expect)


@pytest.mark.parametrize("fmt", [POSIT8_2, POSIT16_2], ids=lambda f: f.name)
@pytest.mark.parametrize("mnk", [(16, 16, 16), (64, 48, 32), (100, 60, 130)],
                         ids=str)
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16], ids=str)
def test_matmul_kernel_vs_ref(fmt, mnk, xdtype):
    m, n, k = mnk
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (m, k)), xdtype)
    # realistic weights (encoded), not raw random codes: random posit16
    # codes span ~1e33 of dynamic range, where accumulation *order* (not the
    # kernel) dominates the comparison
    w = rng.normal(0, 1, (k, n)).astype(np.float32)
    w_codes = np.asarray(posit.encode_f32(w, fmt))
    got = posit_matmul(x, w_codes, fmt, blocks=(32, 32, 16), interpret=True)
    want = ref.posit_matmul_ref(x, w_codes, fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


def test_matmul_kernel_with_scale():
    fmt = POSIT8_2
    m, k, n = 32, 64, 24
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    w = rng.normal(0, 0.02, (k, n)).astype(np.float32)
    qt = quant.quantize(w, fmt, axis=0)  # per-output-channel scale
    got = qt_matmul(x, qt, blocks=(16, 16, 16), interpret=True)
    want = x @ quant.dequantize(qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    # end-to-end quantized matmul approximates the f32 matmul
    full = np.asarray(x @ jnp.asarray(w))
    rel = np.linalg.norm(np.asarray(got) - full) / np.linalg.norm(full)
    assert rel < 0.05, rel


@pytest.mark.parametrize("mnk", [(33, 17, 47), (65, 129, 31), (1, 200, 7)],
                         ids=str)
def test_matmul_kernel_padding_edges(mnk):
    """Non-block-multiple M/N/K: the zero-padded tail must not leak into
    the result (posit code 0 decodes to 0.0, but scale rows are padded
    too)."""
    m, n, k = mnk
    fmt = POSIT8_2
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    w = rng.normal(0, 1, (k, n)).astype(np.float32)
    w_codes = np.asarray(posit.encode_f32(w, fmt))
    scale = rng.uniform(0.5, 2.0, (n,)).astype(np.float32)
    got = posit_matmul(x, w_codes, fmt, scale=scale, blocks=(32, 32, 32),
                       interpret=True)
    want = np.asarray(ref.posit_matmul_ref(x, w_codes, fmt)) * scale
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-4)


def test_matmul_scale_shape_validation():
    """Scalar and (N,)/(1,N) scales work; (N,1) and other shapes raise
    instead of silently flattening into the wrong axis."""
    fmt = POSIT8_2
    rng = np.random.default_rng(8)
    m, k, n = 16, 32, 24
    x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    w_codes = np.asarray(
        posit.encode_f32(rng.normal(0, 1, (k, n)).astype(np.float32), fmt))
    base = np.asarray(posit_matmul(x, w_codes, fmt, blocks=(16, 16, 16),
                                   interpret=True))
    got0 = posit_matmul(x, w_codes, fmt, scale=jnp.float32(2.0),
                        blocks=(16, 16, 16), interpret=True)  # 0-d scalar
    np.testing.assert_allclose(np.asarray(got0), 2.0 * base, rtol=1e-6)
    sv = jnp.asarray(rng.uniform(0.5, 2.0, (n,)), jnp.float32)
    got1 = posit_matmul(x, w_codes, fmt, scale=sv, blocks=(16, 16, 16),
                        interpret=True)
    got2 = posit_matmul(x, w_codes, fmt, scale=sv.reshape(1, n),
                        blocks=(16, 16, 16), interpret=True)
    np.testing.assert_allclose(np.asarray(got1), np.asarray(got2), rtol=1e-6)
    with pytest.raises(ValueError, match="scale"):
        posit_matmul(x, w_codes, fmt, scale=sv.reshape(n, 1),
                     blocks=(16, 16, 16), interpret=True)
    with pytest.raises(ValueError, match="scale"):
        posit_matmul(x, w_codes, fmt, scale=jnp.ones((n - 1,), jnp.float32),
                     blocks=(16, 16, 16), interpret=True)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 80), st.integers(1, 80), st.integers(1, 80),
       st.sampled_from([0, 1, 2]))
def test_matmul_kernel_shape_property(m, n, k, es):
    """Any (m, n, k) with any es: kernel == ref within accumulation tol."""
    fmt = PositFormat(f"p8_{es}", 8, es=es)
    rng = np.random.default_rng(m * 83 + n * 7 + k)
    x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    w = rng.normal(0, 1, (k, n)).astype(np.float32)
    w_codes = np.asarray(posit.encode_f32(w, fmt))
    got = posit_matmul(x, w_codes, fmt, blocks=(32, 32, 32), interpret=True)
    want = ref.posit_matmul_ref(x, w_codes, fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
