"""Posit-packed serving (the paper's decode-on-read datapath at scale):
packed weights + packed KV ring must stay functionally close to the bf16
reference and actually shrink HBM bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import QuantizedTensor
from repro.core.transprecision import BF16, SERVE_P8, SERVE_P16, pack_params
from repro.models import lm
from repro.models.serve_model import decode_step, init_cache


@pytest.mark.parametrize("arch", ["llama3-8b", "recurrentgemma-9b"])
def test_packed_decode_close_to_bf16(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.ones((2, 1), jnp.int32)
    l0, _ = decode_step(params, init_cache(cfg, 2, 16), tok, cfg, BF16)

    pp = pack_params(params, SERVE_P16)
    cache = init_cache(cfg, 2, 16, policy=SERVE_P16)
    l1, c1 = decode_step(pp, cache, tok, cfg, SERVE_P16)
    corr = np.corrcoef(np.asarray(l0, np.float32).ravel(),
                       np.asarray(l1, np.float32).ravel())[0, 1]
    assert corr > 0.99, corr
    # ring stays packed across steps
    for _ in range(3):
        l1, c1 = decode_step(pp, c1, tok, cfg, SERVE_P16)
    assert np.isfinite(np.asarray(l1, np.float32)).all()


def test_packed_weights_shrink_storage():
    cfg = get_config("llama3-8b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    pp = pack_params(params, SERVE_P8)
    qts = [l for l in jax.tree_util.tree_leaves(
        pp, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]
    assert qts, "no leaves were packed"
    for qt in qts:
        assert qt.data.dtype == jnp.uint8
    # packed KV ring dtype
    cache = init_cache(cfg, 2, 16, policy=SERVE_P8)
    assert cache["blocks"][0]["k"].dtype == jnp.uint8


def test_packed_roundtrip_error_bounded():
    """posit8 with per-channel pow2 scale: rel err per weight < 10%
    on N(0, 0.05)-scaled weights (tapered precision centred by scale)."""
    from repro.core.quant import quantize, dequantize
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((64, 32)) * 0.05, jnp.float32)
    qt = quantize(w, "posit8_2", axis=0)
    back = dequantize(qt)
    rel = np.abs(np.asarray(back) - np.asarray(w)) / (np.abs(w) + 1e-3)
    assert float(np.median(rel)) < 0.1
