"""Async orchestrator tests: backpressure, admission timeouts,
out-of-order completion, and streaming-callback identity with the
synchronous ``engine.serve`` loop."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.serve.orchestrator import (Orchestrator, OrchestratorConfig,
                                      StreamingRequest)

MAX_LEN = 64


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in (4, 11, 7, 5)]
    return cfg, params, prompts


def _engine(cfg, params, max_batch=2, **kw):
    return ServingEngine(cfg, params,
                         ServeConfig(max_batch=max_batch, max_len=MAX_LEN,
                                     **kw))


def test_streams_and_callbacks_match_engine_serve(smoke_model):
    cfg, params, prompts = smoke_model
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=8)
            for i, p in enumerate(prompts)]
    _engine(cfg, params).serve(reqs)
    ref = [list(r.out_tokens) for r in reqs]

    got = {}
    def cb(sreq, ids, piece):
        got.setdefault(id(sreq), []).extend(ids)
        assert threading.current_thread().name == "orch-detok"
    with Orchestrator(_engine(cfg, params)) as orch:
        sreqs = [StreamingRequest(p, max_new=8, on_token=cb)
                 for p in prompts]
        for s in sreqs:
            assert orch.submit(s, timeout=60.0)
        for s in sreqs:
            assert s.wait(120.0)
    assert [s.out_tokens for s in sreqs] == ref
    assert [got[id(s)] for s in sreqs] == ref        # callback stream too
    for s in sreqs:
        assert s.error is None and s.ttft_s is not None
        assert len(s.token_t) == len(s.out_tokens)
        assert s.out_text                       # default byte detokenizer
    assert orch.stats["finished"] == len(sreqs)


def test_admission_timeout_backpressure(smoke_model):
    cfg, params, prompts = smoke_model
    ocfg = OrchestratorConfig(max_queue=1)
    with Orchestrator(_engine(cfg, params), ocfg) as orch:
        a = StreamingRequest(prompts[0], max_new=32)
        assert orch.submit(a, timeout=10.0)
        # the single in-flight permit is held until `a` finishes, so a
        # second submit must time out instead of growing the queue
        b = StreamingRequest(prompts[1], max_new=4)
        assert not orch.submit(b, timeout=0.05)
        assert orch.stats["admission_timeouts"] == 1
        assert a.wait(120.0)
        assert orch.submit(b, timeout=60.0)      # permit released
        assert b.wait(120.0)
    assert len(a.out_tokens) == 32 and len(b.out_tokens) == 4


def test_out_of_order_completion(smoke_model):
    cfg, params, prompts = smoke_model
    with Orchestrator(_engine(cfg, params)) as orch:
        slow = StreamingRequest(prompts[0], max_new=48)
        fast = StreamingRequest(prompts[1], max_new=2)
        assert orch.submit(slow, timeout=30.0)
        assert orch.submit(fast, timeout=30.0)
        assert fast.wait(120.0)
        # submitted first, but still decoding when `fast` finished
        assert not slow.done
        assert slow.wait(120.0)
    assert len(fast.out_tokens) == 2 and len(slow.out_tokens) == 48


def test_never_admissible_request_is_rejected(smoke_model):
    cfg, params, _ = smoke_model
    with Orchestrator(_engine(cfg, params)) as orch:
        bad = StreamingRequest(list(range(MAX_LEN + 1)), max_new=4)
        assert orch.submit(bad, timeout=10.0)
        assert bad.wait(60.0)
    assert bad.error is not None and "max_len" in bad.error
    assert bad.out_tokens == []
    assert orch.stats["rejected"] == 1


def test_submit_after_close_raises(smoke_model):
    cfg, params, prompts = smoke_model
    orch = Orchestrator(_engine(cfg, params))
    orch.close()
    with pytest.raises(RuntimeError, match="closed"):
        orch.submit(StreamingRequest(prompts[0]))


def test_text_prompt_roundtrip(smoke_model):
    cfg, params, _ = smoke_model
    with Orchestrator(_engine(cfg, params)) as orch:
        s = StreamingRequest("hello edge", max_new=4)
        assert orch.submit(s, timeout=30.0)
        assert s.wait(120.0)
    assert len(s.out_tokens) == 4
    assert len(s.out_text) > 0


def test_cancel_before_admission_and_after_finish(smoke_model):
    """Error-path ordering: a cancel set before the scheduler ever sees
    the request terminates it without engine work; a cancel after the
    stream finished is a no-op (the first terminal transition wins)."""
    cfg, params, prompts = smoke_model
    eng = _engine(cfg, params)
    with Orchestrator(eng, OrchestratorConfig()) as orch:
        early = StreamingRequest(prompts[0], max_new=8)
        early.cancel()                       # cancelled while queued
        assert orch.submit(early, timeout=30.0)
        assert early.wait(60.0)
        assert early.error == "cancelled" and early.out_tokens == []

        done = StreamingRequest(prompts[1], max_new=4)
        assert orch.submit(done, timeout=30.0)
        assert done.wait(120.0)
        assert done.error is None
        done.cancel()                        # post-terminal: no-op
        assert done.error is None and len(done.out_tokens) == 4
    # a terminal stream stays terminal through close() too
    assert done.error is None


def test_lifecycle_stamps_on_every_terminal_path(smoke_model):
    """Every terminal path — finished, rejected, cancelled — carries
    monotonic submit/finish stamps; richer paths add the middle ones."""
    cfg, params, prompts = smoke_model
    eng = _engine(cfg, params)
    with Orchestrator(eng, OrchestratorConfig()) as orch:
        ok = StreamingRequest(prompts[0], max_new=4)
        rej = StreamingRequest(list(range(MAX_LEN + 1)), max_new=4)
        can = StreamingRequest(prompts[1], max_new=8)
        can.cancel()
        for s in (ok, rej, can):
            assert orch.submit(s, timeout=30.0)
        for s in (ok, rej, can):
            assert s.wait(120.0)
    full = ok.lifecycle()
    assert list(full) == ["submit", "admit", "prefill_done",
                          "insert_done", "first_token", "finish"]
    assert list(full.values()) == sorted(full.values())
    d = ok.lifecycle_deltas()
    assert d["total_s"] >= d["ttft_s"] >= d["queue_wait_s"] >= 0
    for s in (rej, can):                      # terminal without decode
        lc = s.lifecycle()
        assert "submit" in lc and "finish" in lc
        assert lc["finish"] >= lc["submit"]
        assert "first_token" not in lc


def test_wait_vs_error_vs_done_ordering(smoke_model):
    """``wait`` returning True implies the terminal fields are already
    readable: done is set last, after error/out_tokens/finish_t."""
    cfg, params, prompts = smoke_model
    eng = _engine(cfg, params)
    with Orchestrator(eng, OrchestratorConfig(deadline_s=0.05)) as orch:
        s = StreamingRequest(prompts[0], max_new=100_000)
        assert orch.submit(s, timeout=30.0)
        assert s.wait(60.0)
        # no further settling: the terminal state is fully published
        assert s.done and s.error == "deadline" and s.finish_t > 0
        assert s.lifecycle()["finish"] >= s.lifecycle()["submit"]
    assert eng.allocator is None or eng.allocator.live_pages == 0
