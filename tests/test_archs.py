"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs:
  * one forward pass        — output shapes + finite values,
  * one train step          — loss finite, params updated,
  * prefill + N decode steps vs. full forward — logits consistency
    (the serving path must agree with the training path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, SHAPES, shape_applicable, cells
from repro.core.transprecision import BF16, PAPER_EDGE
from repro.data.pipeline import make_pipeline
from repro.models import lm
from repro.models.serve_model import decode_step, init_cache, prefill
from repro.optim import AdamWConfig
from repro.train.step import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, seed=0):
    pipe = make_pipeline(cfg, global_batch=B, seq_len=S, seed=seed)
    return pipe(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    logits, aux = lm.forward(params, _batch(cfg), cfg)
    assert logits.shape == (B, S, cfg.vocab_pad)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates(arch):
    cfg = get_config(arch, smoke=True)
    opt = AdamWConfig(total_steps=4, warmup_steps=1)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    before = jax.tree.map(np.asarray, state.params)
    state2, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    # at least one weight leaf moved
    moved = any(
        not np.array_equal(a, np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(before),
                        jax.tree_util.tree_leaves(state2.params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) then decode(tok) must reproduce forward() logits."""
    import dataclasses
    cfg = get_config(arch, smoke=True)
    if cfg.family == "moe":
        # dropless routing: capacity dropping is batch-length-dependent by
        # construction, so path-consistency is only defined without drops
        cfg = dataclasses.replace(cfg, capacity_factor=0.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits_full, _ = lm.forward(params, batch, cfg)

    if cfg.family == "vlm":
        pre = {"embeds": batch["embeds"][:, :-1]}
        tok = batch["embeds"][:, -1:]
    else:
        pre = {k: v[:, :-1] for k, v in batch.items() if k == "tokens"}
        if cfg.family == "audio":
            pre["frames"] = batch["frames"]
        tok = batch["tokens"][:, -1:]
    last, cache = prefill(params, pre, cfg, max_len=S)
    if cfg.family == "vlm":
        dec, _ = decode_step(params, cache, None, cfg, embeds=tok)
    else:
        dec, _ = decode_step(params, cache, tok, cfg)

    # prefill's last-position logits == forward at position S-2
    # (bf16 models; flash vs dense attention accumulate in different orders)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(logits_full[:, -2], np.float32), rtol=5e-2, atol=5e-2)
    # decode step after prefill == forward at the last position
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_tc_policy_changes_forward(arch):
    """The paper's TC reconfiguration: P(8,2) policy must actually quantize
    (different logits) while keeping the model functional (finite loss)."""
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    l_bf16, _ = lm.forward(params, batch, cfg, BF16)
    l_posit, _ = lm.forward(params, batch, cfg, PAPER_EDGE)
    assert np.isfinite(np.asarray(l_posit, np.float32)).all()
    assert not np.allclose(np.asarray(l_bf16, np.float32),
                           np.asarray(l_posit, np.float32))


def test_full_configs_match_assignment():
    """The full configs carry the exact published hyperparameters."""
    expect = {
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab=50280),
        "llama3-8b": dict(n_layers=32, d_model=4096, n_heads=32,
                          n_kv_heads=8, d_ff=14336, vocab=128256),
        "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab=49155),
        "qwen3-4b": dict(n_layers=36, d_model=2560, n_heads=32,
                         n_kv_heads=8, d_ff=9728, vocab=151936, qk_norm=True),
        "starcoder2-15b": dict(n_layers=40, d_model=6144, n_heads=48,
                               n_kv_heads=4, d_ff=24576, vocab=49152),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12,
                            n_kv_heads=2, d_ff=8960, vocab=151936,
                            mrope=True),
        "recurrentgemma-9b": dict(n_layers=38, d_model=4096, n_heads=16,
                                  n_kv_heads=1, d_ff=12288, vocab=256000),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=6400, vocab=32064,
                                     moe_experts=16, moe_topk=2),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv_heads=8, d_ff=512, vocab=49155,
                                     moe_experts=32, moe_topk=8),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 n_kv_heads=20, d_ff=5120, vocab=51866,
                                 enc_layers=32, enc_seq=1500),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_cells_inventory():
    """40 assigned cells; long_500k runs exactly for the 2 recurrent archs."""
    cs = list(cells())
    assert len(cs) == 40
    runs = [(a, s) for a, s, ok, _ in cs if ok]
    skips = [(a, s) for a, s, ok, _ in cs if not ok]
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    assert ("mamba2-2.7b", "long_500k") in runs
    assert ("recurrentgemma-9b", "long_500k") in runs


def test_param_counts_plausible():
    """Sanity-check full-config parameter counts against the names."""
    import numpy as np
    counts = {a: get_config(a).param_count() for a in
              ["llama3-8b", "mamba2-2.7b", "qwen3-4b",
               "phi3.5-moe-42b-a6.6b", "granite-moe-1b-a400m"]}
    assert 7.5e9 < counts["llama3-8b"] < 9.0e9
    assert 2.4e9 < counts["mamba2-2.7b"] < 3.2e9
    assert 3.2e9 < counts["qwen3-4b"] < 5.0e9
    assert 38e9 < counts["phi3.5-moe-42b-a6.6b"] < 46e9
    assert 0.9e9 < counts["granite-moe-1b-a400m"] < 1.6e9
