"""MoE dispatch equivalence: the O(T*E*C) GShard einsum dispatch and the
O(T*k + E*C*d) scatter dispatch must produce identical outputs (same
routing, same capacity-drop semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # offline CI: vendored deterministic fallback
    from _propcheck import given, settings, strategies as st

from repro.models.moe import init_moe, moe_ffn


def _run(dispatch, x, params, top_k, cap):
    out, aux = moe_ffn(params, x, top_k=top_k, capacity_factor=cap,
                       dispatch=dispatch)
    return np.asarray(out, np.float32), float(aux)


@pytest.mark.parametrize("top_k,cap", [(2, 1.25), (1, 1.0), (4, 2.0)])
def test_scatter_equals_einsum(top_k, cap):
    rng = np.random.default_rng(0)
    d, ff, ne = 32, 48, 8
    params = init_moe(jax.random.PRNGKey(0), d, ff, ne, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, d)), jnp.float32)
    o1, a1 = _run("einsum", x, params, top_k, cap)
    o2, a2 = _run("scatter", x, params, top_k, cap)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)
    assert abs(a1 - a2) < 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bs=st.sampled_from([(1, 8), (3, 5)]),
       topk=st.integers(1, 3))
def test_scatter_equals_einsum_property(seed, bs, topk):
    rng = np.random.default_rng(seed)
    d, ff, ne = 16, 24, 4
    params = init_moe(jax.random.PRNGKey(seed), d, ff, ne, jnp.float32)
    x = jnp.asarray(rng.standard_normal((*bs, d)), jnp.float32)
    o1, _ = _run("einsum", x, params, topk, 1.5)
    o2, _ = _run("scatter", x, params, topk, 1.5)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


def test_gradients_match():
    rng = np.random.default_rng(1)
    d, ff, ne = 16, 24, 4
    params = init_moe(jax.random.PRNGKey(1), d, ff, ne, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, d)), jnp.float32)

    def loss(p, dispatch):
        out, aux = moe_ffn(p, x, top_k=2, capacity_factor=1.5,
                           dispatch=dispatch)
        return jnp.sum(out ** 2) + 0.01 * aux

    g1 = jax.grad(loss)(params, "einsum")
    g2 = jax.grad(loss)(params, "scatter")
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)
