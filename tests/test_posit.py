"""Posit codec + arithmetic: vectorized JAX vs exact Python-integer oracle.

Exhaustive where tractable (all 8-bit codes & pairs; all 16-bit codes),
hypothesis property sweeps elsewhere.  Also pins the paper's worked examples.
"""
import numpy as np
import pytest
from fractions import Fraction

import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # offline CI: vendored deterministic fallback
    from _propcheck import given, settings, strategies as st

from repro.core import posit, posit_ref
from repro.core.formats import (
    POSIT8_0, POSIT8_1, POSIT8_2, POSIT16_0, POSIT16_1, POSIT16_2, POSIT32_2,
    PositFormat,
)

SMALL_FMTS = [POSIT8_0, POSIT8_1, POSIT8_2, POSIT16_0, POSIT16_1, POSIT16_2]
F8 = [POSIT8_0, POSIT8_1, POSIT8_2]


# ---------------------------------------------------------------------------
# Paper worked examples
# ---------------------------------------------------------------------------

def test_paper_example_encode_00024():
    """§II: 0.00024 encodes to P(8,2) = 0 0001 00 0 (= 0x08), err ~1.6%."""
    code = posit_ref.encode(0.00024, 8, 2)
    assert code == 0b00001000
    val = posit_ref.to_float(code, 8, 2)
    assert abs(val - 0.00024) / 0.00024 < 0.02
    # vectorized agrees
    jcode = posit.encode_f32(jnp.float32(0.00024), POSIT8_2)
    assert int(jcode) == 0b00001000


def test_paper_example_decode_01110100():
    """§III-C: P(8,2)=01110100 has K=2; value = useed^2 * 2^E * 1.F."""
    s, K, E, f_len, F = posit_ref.decode_fields(0b01110100, 8, 2)
    assert (s, K) == (0, 2)
    assert E == 2 and F == 0  # E bits "10" after the regime+stop
    assert posit_ref.to_float(0b01110100, 8, 2) == 2.0 ** (4 * 2 + 2)
    # thermometer vector: exactly r=3 ones (paper's V for this operand)
    v, r, k = posit.thermometer_decode(jnp.uint8(0b01110100), POSIT8_2)
    assert int(r) == 3 and int(k) == 2
    assert np.asarray(v).sum() == 3


def test_fp8_underflow_contrast():
    """§II: 0.00024 underflows to 0 in 8-bit FP (e4m3) but not in P(8,2)."""
    fp8 = np.float32(jnp.float8_e4m3fn(0.00024).astype(jnp.float32))
    assert fp8 == 0.0
    assert posit_ref.to_float(posit_ref.encode(0.00024, 8, 2), 8, 2) != 0.0


# ---------------------------------------------------------------------------
# Oracle self-consistency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", SMALL_FMTS, ids=lambda f: f.name)
def test_oracle_roundtrip_and_monotone(fmt):
    n, es = fmt.bits, fmt.es
    vals = posit_ref.all_values(n, es)
    # codes as signed ints sort identically to their real values (posit
    # ordering property) — NaR excluded
    codes = np.arange(1 << n, dtype=np.uint64)
    signed = codes.astype(np.int64)
    signed[signed >= (1 << (n - 1))] -= 1 << n
    ok = ~np.isnan(vals)
    order = np.argsort(signed[ok], kind="stable")
    assert np.all(np.diff(vals[ok][order]) > 0)
    # encode(decode(c)) == c for every code
    for c in range(1 << n):
        if np.isnan(vals[c]):
            continue
        assert posit_ref.encode(vals[c], n, es) == c, (c, vals[c])


def test_oracle_rne_bitspace_ties_p8():
    """Bit-level RNE (softposit semantics): the tie point between adjacent
    codes (c, c+1) is the value of the extended bit string `c·2 + 1` read as a
    P(n+1, es) posit.  Ties go to the even code; either side resolves to the
    adjacent code."""
    n, es = 8, 2
    for c in list(range(1, 127)) + list(range(129, 255)):
        tie = posit_ref.to_fraction(((c << 1) | 1) & 0x1FF, n + 1, es)
        got = posit_ref.encode_fraction(tie, n, es)
        lo_c, hi_c = c, (c + 1) & 0xFF
        assert got in (lo_c, hi_c), (c, got)
        assert got % 2 == 0, c  # ties to even code
        lo = posit_ref.to_fraction(lo_c, n, es)
        hi = posit_ref.to_fraction(hi_c, n, es)
        eps = abs(hi - lo) / 4096
        # signed-code order: lo_c < tie < hi_c in value
        assert posit_ref.encode_fraction(tie - eps, n, es) == min(lo_c, hi_c, key=lambda k: posit_ref.to_fraction(k, n, es))
        assert posit_ref.encode_fraction(tie + eps, n, es) == max(lo_c, hi_c, key=lambda k: posit_ref.to_fraction(k, n, es))


def test_oracle_saturation():
    n, es = 8, 2
    mx = posit_ref.maxpos(n, es)
    mn = posit_ref.minpos(n, es)
    assert posit_ref.encode_fraction(mx * 1000, n, es) == 0x7F
    assert posit_ref.encode_fraction(mn / 1000, n, es) == 0x01
    assert posit_ref.encode_fraction(-mx * 1000, n, es) == 0x81
    assert posit_ref.encode(float("inf"), n, es) == 0x80
    assert posit_ref.encode(float("nan"), n, es) == 0x80


# ---------------------------------------------------------------------------
# Vectorized codec vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", SMALL_FMTS, ids=lambda f: f.name)
def test_decode_matches_oracle_exhaustive(fmt):
    n, es = fmt.bits, fmt.es
    codes = np.arange(1 << n, dtype=fmt.np_storage_dtype)
    got = np.asarray(posit.decode_to_f32_jit(codes, fmt), dtype=np.float64)
    want = posit_ref.all_values(n, es)  # exact in f64; values fit f32 for n<=16
    np.testing.assert_array_equal(got[~np.isnan(want)], want[~np.isnan(want)])
    assert np.isnan(got[posit_ref.nar_code(n)])


@pytest.mark.parametrize("fmt", SMALL_FMTS, ids=lambda f: f.name)
def test_encode_roundtrip_exhaustive(fmt):
    codes = np.arange(1 << fmt.bits, dtype=fmt.np_storage_dtype)
    vals = posit.decode_to_f32_jit(codes, fmt)
    back = np.asarray(posit.encode_f32_jit(vals, fmt))
    np.testing.assert_array_equal(back, codes)


def test_encode_f32_random_matches_oracle():
    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.normal(0, 1, 2000), rng.normal(0, 1e-6, 2000),
        rng.normal(0, 1e6, 2000), np.array([0.0, 1.0, -1.0, 0.5, 3.14159]),
    ]).astype(np.float32)
    for fmt in [POSIT8_2, POSIT16_2, POSIT16_0, POSIT32_2]:
        got = np.asarray(posit.encode_f32_jit(x, fmt))
        want = np.array([posit_ref.encode(float(v), fmt.bits, fmt.es) for v in x],
                        dtype=fmt.np_storage_dtype)
        np.testing.assert_array_equal(got, want)


def test_decode32_rne_to_f32():
    """P(32,2) decode to f32 must equal f32(np rounding of the exact value)."""
    rng = np.random.default_rng(1)
    codes = rng.integers(0, 1 << 32, 4000, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(posit.decode_to_f32_jit(codes, POSIT32_2))
    want = np.array([posit_ref.to_float(int(c), 32, 2) for c in codes],
                    dtype=np.float64).astype(np.float32)
    nn = ~np.isnan(want)
    np.testing.assert_array_equal(got[nn], want[nn])


@pytest.mark.parametrize("fmt", F8, ids=lambda f: f.name)
def test_thermometer_equals_lut_decode(fmt):
    """Alg-1 fidelity: LUT[popcount(V)] == regime K for every code (lead=1
    plane; the complement plane via T transform), proving the paper's LUT
    degenerates to popcount."""
    n = fmt.bits
    codes = np.arange(1 << n, dtype=fmt.np_storage_dtype)
    v, r, k = posit.thermometer_decode(codes, fmt)
    v, r, k = (np.asarray(x).astype(np.int64) for x in (v, r, k))
    # thermometer property: V is monotone (no 0 after a 1, scanning i up)
    assert np.all(np.diff(v.astype(np.int8), axis=-1) >= 0)
    assert np.array_equal(v.sum(-1), r)
    lut = posit.regime_lut(fmt)
    lead = (codes >> (n - 2)) & 1
    k_lut = np.where(lead == 1, lut[r], -r)
    np.testing.assert_array_equal(k, k_lut)
    # against the oracle's field decode for positive, nonzero codes
    for c in range(1, 1 << (n - 1)):
        _, K, *_ = posit_ref.decode_fields(c, n, fmt.es)
        assert k[c] == K, c


# ---------------------------------------------------------------------------
# Exact arithmetic vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", F8, ids=lambda f: f.name)
def test_add_mul_exhaustive_p8(fmt):
    n, es = fmt.bits, fmt.es
    a = np.repeat(np.arange(256, dtype=np.uint8), 256)
    b = np.tile(np.arange(256, dtype=np.uint8), 256)
    got_add = np.asarray(posit.add_jit(a, b, fmt))
    got_mul = np.asarray(posit.mul_jit(a, b, fmt))
    want_add = np.empty_like(got_add)
    want_mul = np.empty_like(got_mul)
    vals = [posit_ref.to_fraction(c, n, es) for c in range(256)]
    nar = posit_ref.nar_code(n)
    for i in range(65536):
        va, vb = vals[a[i]], vals[b[i]]
        if va is None or vb is None:
            want_add[i] = want_mul[i] = nar
        else:
            want_add[i] = posit_ref.encode_fraction(va + vb, n, es)
            want_mul[i] = posit_ref.encode_fraction(va * vb, n, es)
    bad_a = np.nonzero(got_add != want_add)[0]
    bad_m = np.nonzero(got_mul != want_mul)[0]
    assert bad_a.size == 0, f"{bad_a.size} add mismatches, first: " + str(
        [(hex(a[i]), hex(b[i]), hex(got_add[i]), hex(want_add[i])) for i in bad_a[:5]])
    assert bad_m.size == 0, f"{bad_m.size} mul mismatches, first: " + str(
        [(hex(a[i]), hex(b[i]), hex(got_mul[i]), hex(want_mul[i])) for i in bad_m[:5]])


@settings(max_examples=300, deadline=None)
@given(st.integers(0, 65535), st.integers(0, 65535),
       st.sampled_from([0, 1, 2]))
def test_add_mul_p16_hypothesis(a, b, es):
    fmt = PositFormat(f"p16_{es}", 16, es=es)
    n = 16
    va = posit_ref.to_fraction(a, n, es)
    vb = posit_ref.to_fraction(b, n, es)
    ac = np.uint16(a)
    bc = np.uint16(b)
    got_add = int(posit.add(ac, bc, fmt))
    got_mul = int(posit.mul(ac, bc, fmt))
    if va is None or vb is None:
        assert got_add == got_mul == posit_ref.nar_code(n)
    else:
        assert got_add == posit_ref.encode_fraction(va + vb, n, es)
        assert got_mul == posit_ref.encode_fraction(va * vb, n, es)


@settings(max_examples=200, deadline=None)
@given(st.floats(float(np.float32(-1e30)), float(np.float32(1e30)),
                 allow_nan=False, width=32))
def test_encode32_matches_oracle_hypothesis(x):
    got = int(posit.encode_f32(jnp.float32(x), POSIT32_2))
    want = posit_ref.encode(float(np.float32(x)), 32, 2)
    assert got == want


def test_sub_and_cancellation():
    fmt = POSIT8_2
    a = posit.encode_f32(jnp.float32(1.5), fmt)
    assert int(posit.sub(a, a, fmt)) == 0
    # catastrophic cancellation stays exact (1.25 and 0.25 are representable)
    x = posit.encode_f32(jnp.float32(1.25), fmt)
    y = posit.encode_f32(jnp.float32(1.0), fmt)
    d = posit.sub(x, y, fmt)
    assert float(posit.decode_to_f32(d, fmt)) == 0.25


def test_dot_exact_small():
    fmt = POSIT8_2
    rng = np.random.default_rng(3)
    a = rng.normal(0, 1, (4, 6)).astype(np.float32)
    b = rng.normal(0, 1, (6, 5)).astype(np.float32)
    ac = np.asarray(posit.encode_f32(a, fmt))
    bc = np.asarray(posit.encode_f32(b, fmt))
    got = np.asarray(posit.matmul_exact(ac, bc, fmt))
    # oracle: sequential posit MACs in the same order
    want = np.zeros((4, 5), dtype=np.uint8)
    for i in range(4):
        for j in range(5):
            acc = 0
            for k in range(6):
                p = posit_ref.mul(int(ac[i, k]), int(bc[k, j]), 8, 2)
                acc = posit_ref.add(acc, p, 8, 2)
            want[i, j] = acc
    np.testing.assert_array_equal(got, want)


def test_posit_bias_extension():
    """Exponent-biased posit (beyond-paper): decode(encode(x)) scales by 2^bias."""
    base = POSIT8_2
    biased = PositFormat("posit8_2_b6", 8, es=2, bias=-6)
    x = jnp.float32(0.02)  # typical NN weight scale
    # biased format centers tapered precision near 2^-6
    e1 = posit.decode_to_f32(posit.encode_f32(x, base), base)
    e2 = posit.decode_to_f32(posit.encode_f32(x, biased), biased)
    assert abs(float(e2) - 0.02) <= abs(float(e1) - 0.02)
    # roundtrip of representable values is exact
    v = posit.decode_to_f32(jnp.uint8(0b01000000), biased)
    assert int(posit.encode_f32(v, biased)) == 0b01000000
