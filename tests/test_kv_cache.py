"""Posit-packed KV cache: kernel-vs-reference bit-exactness, round-trip
error bounds per format, and engine-level greedy-decode equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import posit
from repro.core.formats import POSIT4_1, POSIT8_2, POSIT16_2
from repro.core.transprecision import BF16, KV_FORMATS, kv_storage
from repro.kernels import kv_cache as kvk
from repro.models import lm
from repro.serve.engine import Request, ServeConfig, ServingEngine

FMTS = [("posit16", POSIT16_2, False), ("posit8", POSIT8_2, False),
        ("posit4", POSIT4_1, True)]


# ---------------------------------------------------------------------------
# Codec round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,fmt,packed", FMTS, ids=lambda x: str(x))
def test_kv_roundtrip_within_posit_ulp(name, fmt, packed):
    """encode->decode of scaled rows stays within one posit ULP per value:
    the per-row pow2 scale is exact, so the only error is the posit RNE,
    bounded by useed^|k| taper — check against the direct posit round-trip
    of the scaled value (which IS the ULP-correct answer)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.3, (4, 6, 16)), jnp.float32)
    codes, scale = kvk.encode_kv_rows(x, fmt, packed)
    back = kvk.decode_kv_rows(codes, scale, fmt, packed)
    # bit-exact vs the scalar posit codec applied to x/scale
    want = posit.decode_to_f32(
        posit.encode_f32(x / scale, fmt), fmt) * scale
    np.testing.assert_array_equal(np.asarray(back), np.asarray(want))
    # and the relative error is format-taper bounded near the row scale
    rel = np.abs(np.asarray(back) - np.asarray(x)) / (np.abs(x) + 1e-6)
    med = float(np.median(rel))
    assert med < {"posit16": 2e-4, "posit8": 0.05, "posit4": 0.5}[name], med


def test_nibble_pack_roundtrip():
    rng = np.random.default_rng(1)
    codes = rng.integers(0, 16, (3, 5, 8)).astype(np.uint8)
    packed = kvk.pack_nibbles(jnp.asarray(codes))
    assert packed.shape == (3, 5, 4)
    np.testing.assert_array_equal(
        np.asarray(kvk.unpack_nibbles(packed)), codes)


# ---------------------------------------------------------------------------
# Pallas kernels vs pure-jnp oracles (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,fmt,packed", FMTS, ids=lambda x: str(x))
def test_kv_append_kernel_bit_exact(name, fmt, packed):
    rng = np.random.default_rng(2)
    b, w, h, hd = 2, 8, 3, 16
    dc = kvk.code_channels(hd, fmt, packed)
    kc = jnp.zeros((b, w, h, dc), fmt.storage_dtype)
    ks = jnp.ones((b, w, h), jnp.float32)
    vc, vs = kc, ks
    for pos in (0, 3, 9):   # incl. ring wrap
        kn = jnp.asarray(rng.normal(0, 0.5, (b, 1, h, hd)), jnp.float32)
        vn = jnp.asarray(rng.normal(0, 2.0, (b, 1, h, hd)), jnp.float32)
        got = kvk.kv_append(kc, ks, vc, vs, kn, vn, pos, fmt,
                            packed=packed, interpret=True)
        want = kvk.kv_append_ref(kc, ks, vc, vs, kn, vn, pos, fmt, packed)
        for g, wv in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(wv))
        kc, ks, vc, vs = got


@pytest.mark.parametrize("name,fmt,packed", FMTS, ids=lambda x: str(x))
@pytest.mark.parametrize("cache_len", [1, 5, 16])
def test_fused_decode_attention_matches_ref(name, fmt, packed, cache_len):
    rng = np.random.default_rng(3)
    b, w, nkv, grp, hd = 2, 16, 2, 3, 8
    kf = rng.normal(0, 1, (b, w, nkv, hd)).astype(np.float32)
    vf = rng.normal(0, 1, (b, w, nkv, hd)).astype(np.float32)
    kc, ks = kvk.encode_kv_rows(jnp.asarray(kf), fmt, packed)
    vc, vs = kvk.encode_kv_rows(jnp.asarray(vf), fmt, packed)
    ks, vs = ks[..., 0], vs[..., 0]
    q = jnp.asarray(rng.normal(0, 1, (b, 1, nkv * grp, hd)), jnp.float32)
    got = kvk.decode_attention(q, kc, ks, vc, vs, cache_len, fmt,
                               packed=packed, block_w=4, interpret=True)
    want = kvk.decode_attention_ref(q, kc, ks, vc, vs, cache_len, fmt,
                                    packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# KV storage resolution + cache footprint
# ---------------------------------------------------------------------------

def test_kv_storage_resolution():
    assert kv_storage(BF16) is None
    p8 = dataclasses.replace(BF16, kv_format="posit8", name="p8")
    spec = kv_storage(p8)
    assert spec.is_posit and spec.fmt.bits == 8 and not spec.packed
    p4 = dataclasses.replace(BF16, kv_format="posit4", name="p4")
    assert kv_storage(p4).packed
    from repro.core.transprecision import SERVE_P16
    legacy = kv_storage(SERVE_P16)
    assert legacy.is_posit and legacy.fmt.bits == 16
    with pytest.raises(KeyError):
        kv_storage(dataclasses.replace(BF16, kv_format="fp7", name="x"))
    # amortized bytes/value at hd=64: posit8 ~0.53x bf16, posit4 <=0.3x
    bf = KV_FORMATS["bf16"].bytes_per_value(64)
    assert KV_FORMATS["posit8"].bytes_per_value(64) / bf < 0.54
    assert KV_FORMATS["posit4"].bytes_per_value(64) / bf <= 0.3


# ---------------------------------------------------------------------------
# Engine-level greedy equivalence
# ---------------------------------------------------------------------------

def _serve_tokens(cfg, params, prompts, kv_format, max_new=8):
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=64,
                                    kv_format=kv_format))
    reqs = [Request(uid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    stats = eng.serve(reqs)
    return [r.out_tokens for r in reqs], stats


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
               for _ in range(4)]
    return cfg, params, prompts


def test_greedy_decode_bf16_equals_f32(smoke_model):
    cfg, params, prompts = smoke_model
    t_f32, _ = _serve_tokens(cfg, params, prompts, "f32")
    t_bf16, s = _serve_tokens(cfg, params, prompts, "bf16")
    assert t_bf16 == t_f32
    assert s["kv_cache_bytes"] < _serve_tokens(
        cfg, params, prompts, "f32", max_new=1)[1]["kv_cache_bytes"]


def test_greedy_decode_posit16_equals_f32(smoke_model):
    """Acceptance: posit16 KV matches the f32 cache on the quickstart-style
    prompt set, at half the f32 cache footprint (codes) + scales."""
    cfg, params, prompts = smoke_model
    t_f32, s32 = _serve_tokens(cfg, params, prompts, "f32")
    t_p16, s16 = _serve_tokens(cfg, params, prompts, "posit16")
    assert t_p16 == t_f32
    assert s16["kv_cache_bytes"] < 0.6 * s32["kv_cache_bytes"]


def test_engine_runs_posit8_and_posit4(smoke_model):
    cfg, params, prompts = smoke_model
    for kvf in ("posit8", "posit4"):
        toks, stats = _serve_tokens(cfg, params, prompts, kvf)
        assert all(len(t) > 0 for t in toks)
        assert stats["tokens"] > 0
