"""Static sharding checks over the FULL assigned configs (metadata only, no
device allocation): every param/optimizer/cache leaf must divide evenly
over the production mesh axes its spec maps it to — catches sharding-rule
regressions in seconds instead of during a 512-way compile."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.core.transprecision import SERVE_P8, pack_params
from repro.launch import mesh as mesh_lib
from repro.launch.specs import decode_specs
from repro.models import lm

AXIS_SIZE = {"pod": 2, "data": 16, "model": 16}


def _axis_size(entry):
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([AXIS_SIZE[a] for a in entry]))
    return AXIS_SIZE[entry]


def _check_tree(tree, specs, what):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves), what
    for (kp, leaf), spec in zip(leaves, spec_leaves):
        path = jax.tree_util.keystr(kp)
        assert len(spec) <= len(leaf.shape), (what, path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, spec):
            n = _axis_size(entry)
            assert dim % n == 0, (
                f"{what}{path}: dim {dim} not divisible by {n} ({spec})")


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg, abstract=True)
    specs = mesh_lib.param_specs(params, fsdp="data")
    _check_tree(params, specs, f"{arch} params")


@pytest.mark.parametrize("arch", ["llama3-8b", "granite-3-8b",
                                  "starcoder2-15b"])
def test_packed_param_specs_divisible(arch):
    cfg = get_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg, abstract=True)
    packed = pack_params(params, SERVE_P8, abstract=True)
    specs = mesh_lib.param_specs(packed, fsdp=None)
    # specs are a prefix tree (one spec per QuantizedTensor); check data
    # leaves against their spec
    flat_p = jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: hasattr(x, "fmt"))
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        shape = leaf.data.shape if hasattr(leaf, "fmt") else leaf.shape
        for dim, entry in zip(shape, spec):
            assert dim % _axis_size(entry) == 0, (arch, shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_divisible(arch):
    ok, _ = shape_applicable(arch, "decode_32k")
    assert ok
    cfg = get_config(arch)
    rules = mesh_lib.serve_rules(
        jax.sharding.Mesh(
            np.array(jax.devices() * 0 + [jax.devices()[0]]).reshape(1, 1),
            ("data", "model")),
        global_batch=SHAPES["decode_32k"].global_batch)
    # use production axis names for divisibility regardless of local mesh
    rules = {"batch": ("data",), "kv_seq": "model", "ffn": "model",
             "vocab": "model", "expert": "model", "heads": None, "seq": None}
    cache, _ = decode_specs(cfg, SHAPES["decode_32k"])
    specs = mesh_lib.cache_specs(cache, cfg, rules)
    _check_tree(cache, specs, f"{arch} cache")


def test_batch_divisibility_rules():
    """batch rule turns off (None) when the global batch doesn't divide."""
    mesh_axes = {"pod": 2, "data": 16, "model": 16}

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = mesh_axes

    r = mesh_lib.train_rules(FakeMesh(), global_batch=256)
    assert r["batch"] == ("pod", "data")
    r1 = mesh_lib.train_rules(FakeMesh(), global_batch=1)   # long_500k
    assert r1["batch"] is None
