"""Observability subsystem: tracer spans, metrics registry, breakdowns.

Covers the guarantees the serving stack leans on:

* span nesting/ordering and online self-time accounting (the basis of
  the per-stage wall-clock attribution);
* histogram percentile accuracy vs exact numpy percentiles;
* registry snapshot round-trip (``from_snapshot(snap).snapshot() ==
  snap`` and JSON-stable);
* Chrome-trace export schema (loadable by chrome://tracing / Perfetto);
* disabled-tracer overhead bound — the hot serving loop keeps its spans
  in place permanently, so ``span()`` with tracing off must stay cheap;
* ``StatsView`` legacy-dict facade semantics;
* end-to-end: a smoke ``ServingEngine`` run produces a consistent
  registry, a valid trace, and a stage breakdown that attributes the
  wall clock.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       StatsView, Tracer, stage_breakdown)
from repro.obs.report import format_breakdown


# ---------------------------------------------------------------- tracer

def test_span_nesting_self_times():
    tr = Tracer(enabled=True)
    with tr.span("outer"):
        time.sleep(0.02)
        with tr.span("inner"):
            time.sleep(0.02)
    st = tr.self_times()
    assert set(st) == {"outer", "inner"}
    assert st["outer"]["count"] == 1 and st["inner"]["count"] == 1
    # outer total covers inner; outer SELF excludes it
    assert st["outer"]["total_s"] >= st["inner"]["total_s"]
    assert st["outer"]["self_s"] == pytest.approx(
        st["outer"]["total_s"] - st["inner"]["total_s"], abs=1e-6)
    # self times tile the outer wall: sum == outer total
    assert (st["outer"]["self_s"] + st["inner"]["self_s"]
            == pytest.approx(st["outer"]["total_s"], abs=1e-6))


def test_span_event_ordering():
    tr = Tracer(enabled=True)
    with tr.span("a"):
        with tr.span("b"):
            pass
    with tr.span("c"):
        pass
    evs = tr.events()
    # events land at close time: b closes before a, a before c
    assert [e["name"] for e in evs] == ["b", "a", "c"]
    b, a, c = evs
    assert a["t0"] <= b["t0"] <= b["t1"] <= a["t1"] <= c["t0"] <= c["t1"]


def test_trace_decorator_and_disabled_passthrough():
    tr = Tracer(enabled=True)

    @tr.trace("work", cat="host")
    def work(x):
        return x + 1

    assert work(1) == 2
    assert tr.self_times()["work"]["count"] == 1
    tr.disable()
    assert work(2) == 3                       # still callable, unrecorded
    assert tr.self_times()["work"]["count"] == 1


def test_thread_aware_stacks():
    """Spans on different threads must not see each other as parents."""
    tr = Tracer(enabled=True)
    go = threading.Event()

    def worker():
        go.wait(5)
        with tr.span("child_thread"):
            time.sleep(0.01)

    t = threading.Thread(target=worker, name="obs-worker")
    with tr.span("main_span"):
        t.start()
        go.set()
        t.join()
    st = tr.self_times()
    # worker span is NOT a child of main_span: main self == main total
    assert st["main_span"]["self_s"] == pytest.approx(
        st["main_span"]["total_s"], abs=1e-6)
    tids = {e["tid"] for e in tr.events()}
    assert len(tids) == 2
    # thread-name metadata makes it into the Chrome trace
    names = {e["args"]["name"] for e in tr.chrome_trace()["traceEvents"]
             if e["ph"] == "M"}
    assert "obs-worker" in names


def test_ring_bounded_aggregates_exact():
    tr = Tracer(capacity=8, enabled=True)
    for _ in range(100):
        with tr.span("tick"):
            pass
    assert len(tr.events()) == 8              # ring dropped old events
    assert tr.self_times()["tick"]["count"] == 100   # aggregates exact


def test_chrome_trace_schema(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("stage.dispatch", cat="engine", n=3):
        pass
    path = tmp_path / "t.trace.json"
    tr.write_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    x = [e for e in evs if e["ph"] == "X"]
    m = [e for e in evs if e["ph"] == "M"]
    assert len(x) == 1 and len(m) >= 1
    ev = x[0]
    for key in ("name", "cat", "pid", "tid", "ts", "dur"):
        assert key in ev
    assert ev["dur"] >= 0 and ev["ts"] >= 0   # µs, relative to epoch
    assert ev["args"] == {"n": 3}
    assert all(e["args"]["name"] for e in m)  # thread_name metadata


def test_disabled_overhead_bound():
    """Hot-loop spans with tracing off must stay near-free (< ~5 µs/call,
    generous for CI noise; the real cost is one attr check + return)."""
    tr = Tracer(enabled=False)
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("hot"):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 5e-6, f"disabled span costs {per_call * 1e6:.2f} µs"
    assert not tr.events() and not tr.self_times()


def test_tracer_reset_and_capacity_validation():
    tr = Tracer(enabled=True)
    with tr.span("x"):
        pass
    tr.reset()
    assert not tr.events() and not tr.self_times()
    with pytest.raises(ValueError):
        Tracer(capacity=0)


# --------------------------------------------------------------- metrics

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("tokens")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("depth")
    g.set(3)
    g.inc(-1)
    assert g.value == 2
    # get-or-create returns the same object; kind mismatch raises
    assert reg.counter("tokens") is c
    with pytest.raises(TypeError):
        reg.gauge("tokens")
    assert "tokens" in reg and "nope" not in reg


@pytest.mark.parametrize("dist", ["lognormal", "uniform"])
def test_histogram_percentiles_vs_numpy(dist):
    rng = np.random.default_rng(0)
    if dist == "lognormal":
        xs = rng.lognormal(mean=-6.0, sigma=1.5, size=5000)   # ~latencies
    else:
        xs = rng.uniform(1e-4, 1e-1, size=5000)
    h = Histogram("lat")
    for x in xs:
        h.observe(x)
    for q in (50, 95, 99):
        exact = float(np.percentile(xs, q))
        approx = h.percentile(q)
        # log-bucketed: relative error bounded by ~one bucket width
        assert abs(approx - exact) / exact < 0.10, (q, approx, exact)
    assert h.count == len(xs)
    assert h.mean == pytest.approx(float(xs.mean()), rel=1e-9)
    assert h.percentile(0) == pytest.approx(float(xs.min()))
    assert h.percentile(100) == pytest.approx(float(xs.max()))


def test_histogram_edge_cases():
    h = Histogram("h", lo=1e-3, hi=1e3)
    assert h.percentile(50) is None           # empty
    h.observe(0.0)                            # sub-lo bucket
    h.observe(1e9)                            # clamped to top bucket
    snap = h.snapshot()
    assert snap["count"] == 2
    assert snap["min"] == 0.0 and snap["max"] == 1e9
    # sub-lo bucket: all we know is "< lo", reported as lo at most
    assert 0.0 <= h.percentile(1) <= h.lo
    with pytest.raises(ValueError):
        Histogram("bad", lo=1.0, hi=0.5)


def test_registry_snapshot_roundtrip():
    reg = MetricsRegistry()
    reg.counter("engine.tokens").inc(42)
    reg.gauge("orch.queue_depth").set(7)
    h = reg.histogram("stage.generate.dispatch_s")
    rng = np.random.default_rng(1)
    for x in rng.lognormal(-5, 1, 300):
        h.observe(float(x))
    snap = reg.snapshot()
    # JSON-stable: survives a dump/load cycle
    snap2 = json.loads(json.dumps(snap))
    restored = MetricsRegistry.from_snapshot(snap2)
    assert restored.snapshot() == snap
    assert restored.counter("engine.tokens").value == 42
    assert (restored.histogram("stage.generate.dispatch_s").percentile(95)
            == pytest.approx(h.percentile(95)))


def test_stats_view_legacy_surface():
    reg = MetricsRegistry()
    sv = StatsView(reg, prefix="engine.")
    sv.bind_counters("tokens", "prefills")
    sv.bind_gauges("peak_live_pages")
    sv["tokens"] += 5                        # dict-style increment
    sv.update(prefills=3)                    # bulk update
    sv["peak_live_pages"] = 9
    assert {**sv} == {"tokens": 5, "prefills": 3, "peak_live_pages": 9}
    assert sv.get("missing", 0) == 0
    assert len(sv) == 3 and sorted(sv) == ["peak_live_pages", "prefills",
                                           "tokens"]
    # registry is the single source of truth
    assert reg.counter("engine.tokens").value == 5
    assert sv.metric_name("tokens") == "engine.tokens"
    # unknown keys auto-bind as gauges (late stats like wall_s)
    sv["evictions"] = 2
    assert reg.gauge("engine.evictions").value == 2
    # bulk reset, as bench warmups do
    sv.update(tokens=0, prefills=0)
    assert sv["tokens"] == 0 and reg.counter("engine.tokens").value == 0


# ---------------------------------------------------------------- report

def test_stage_breakdown_partitions():
    tr = Tracer(enabled=True)
    with tr.span("serve.step"):              # host bucket
        with tr.span("generate.dispatch", cat="engine"):
            time.sleep(0.01)
        with tr.span("generate.device", cat="engine"):
            time.sleep(0.01)
    with tr.span("orch.detok", cat="detok"):  # concurrent: excluded
        time.sleep(0.01)
    wall = 0.05
    bd = stage_breakdown(tr, wall)
    g = bd["stages"]["generate"]
    assert g["calls"] == 1
    assert g["dispatch_s"] == pytest.approx(0.01, rel=0.5)
    assert g["device_s"] == pytest.approx(0.01, rel=0.5)
    assert "serve.step" in bd["host"]
    assert "orch.detok" in bd["concurrent"]
    # attribution sums stages + host but NOT concurrent
    total = (g["dispatch_s"] + g["device_s"] + sum(bd["host"].values()))
    assert bd["attributed_s"] == pytest.approx(total, abs=1e-9)
    assert bd["attributed_s"] + bd["unattributed_s"] == pytest.approx(wall)
    assert 0 < bd["attributed_frac"] <= 1.0
    assert "generate" in format_breakdown(bd)


def test_stage_breakdown_since_window():
    tr = Tracer(enabled=True)
    with tr.span("a.dispatch", cat="engine"):
        time.sleep(0.01)
    snap = tr.self_times()
    with tr.span("b.dispatch", cat="engine"):
        time.sleep(0.01)
    bd = stage_breakdown(tr, 0.02, since=snap)
    assert "b" in bd["stages"] and "a" not in bd["stages"]
    # full-history breakdown still sees both
    assert set(stage_breakdown(tr, 0.02)["stages"]) == {"a", "b"}


# ----------------------------------------------------- engine integration

def test_serving_engine_observability():
    import jax

    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import Request, ServeConfig, ServingEngine

    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=2, max_len=64, kv_format="posit8")
    eng = ServingEngine(cfg, params, scfg,
                        tracer=Tracer(enabled=True))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 6), max_new=4)
            for i in range(3)]
    t0 = time.perf_counter()
    stats = eng.serve(reqs)
    wall = time.perf_counter() - t0

    # legacy stats keys are live views of the registry
    snap = eng.metrics.snapshot()
    assert stats["tokens"] == snap["counters"]["engine.tokens"]
    assert stats["prefills"] == snap["counters"]["engine.prefills"]
    # per-stage latency histograms recorded one observation per call
    assert (snap["histograms"]["stage.generate.dispatch_s"]["count"]
            == stats["decode_steps"])
    assert (snap["histograms"]["stage.prefill.dispatch_s"]["count"]
            == stats["prefills"])

    # breakdown attributes the serve loop's wall clock
    bd = stage_breakdown(eng.tracer, wall)
    assert {"prefill", "insert", "generate"} <= set(bd["stages"])
    assert bd["attributed_frac"] >= 0.9

    # the trace is valid Chrome-trace JSON with engine spans in it
    doc = json.loads(json.dumps(eng.tracer.chrome_trace()))
    names = {e["name"] for e in doc["traceEvents"]}
    assert "generate.dispatch" in names and "generate.device" in names
