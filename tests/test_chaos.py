"""Chaos suite: seeded fault schedules against the hardened serving stack.

Three invariants, asserted under deterministic fault injection
(``repro.serve.faults``):

1. **no hangs** — every submitted request reaches a terminal state
   (tokens done, or a terminal ``error``) within a bounded wait, under
   benign AND lethal fault plans;
2. **no leaks** — the page allocator drains to zero live pages and
   passes ``assert_consistent()`` after every scenario, including
   deadline expiry, cancellation and crash containment;
3. **no blast radius** — streams whose requests were never faulted are
   token-identical to a fault-free run (retries, evictions and a
   neighbour's quarantine must not perturb them).

Plus targeted scenarios per failure mode: transient-retry identity,
persistent-error containment, numeric quarantine with precision-fallback
re-decode (``guard.fallbacks > 0``), ladder exhaustion, pool-dry
eviction, tokenize/detok/scheduler crash containment, the stuck-
scheduler watchdog and leaked-thread detection in ``close``.
"""
import json
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.transprecision import BF16, PRESETS
from repro.models import lm
from repro.serve import (Fault, FaultInjector, FaultPlan, GuardConfig,
                         InjectedFault, Orchestrator, OrchestratorConfig,
                         PageAllocator, Request, RetryPolicy, ServeConfig,
                         ServingEngine, StreamingRequest, fallback_ladder)

MAX_LEN = 64
POLICY = "paper_edge_p8"        # 2 real guard rungs (posit16 -> full)
RETRY = RetryPolicy(backoff_s=0.001, max_backoff_s=0.01)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).tolist()
               for n in (4, 11, 7, 5, 9, 6)]
    return cfg, params, prompts


def _engine(cfg, params, **kw):
    """Paged-overcommit engine (the layout every fault kind can hit:
    pool_dry needs overcommit's evict-don't-raise semantics)."""
    kw.setdefault("policy", POLICY)
    return ServingEngine(
        cfg, params,
        ServeConfig(max_batch=2, max_len=MAX_LEN, kv_layout="paged",
                    page_size=8, page_overcommit=True), **kw)


def _baseline(cfg, params, prompts, max_new):
    """Fault-free greedy token streams, one list per prompt."""
    eng = _engine(cfg, params)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=max_new)
            for i, p in enumerate(prompts)]
    eng.serve(reqs)
    assert all(r.done and r.error is None for r in reqs)
    return [list(r.out_tokens) for r in reqs]


def _assert_drained(eng):
    """Invariant 2: zero live pages + a consistent allocator."""
    assert eng.allocator.live_pages == 0
    eng.allocator.assert_consistent()


# ---------------------------------------------------------------------------
# the headline invariants, over seeded random schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [1, 2, 3])
def test_seeded_chaos_invariants(smoke_model, seed):
    cfg, params, prompts = smoke_model
    max_new = 10
    ref = _baseline(cfg, params, prompts, max_new)

    plan = FaultPlan.random(seed, n=6, rounds=25, slots=2)
    eng = _engine(cfg, params, faults=plan, retry=RETRY, guard=True)
    sreqs = [StreamingRequest(p, max_new=max_new) for p in prompts]
    with Orchestrator(eng, OrchestratorConfig()) as orch:
        for s in sreqs:
            assert orch.submit(s, timeout=60.0)
        for s in sreqs:                      # invariant 1: no hangs
            assert s.wait(120.0), "request never reached a terminal state"
    _assert_drained(eng)                     # invariant 2: no leaks
    # benign plans: every fault kind is recoverable, so no errors at all
    assert all(s.error is None for s in sreqs), [s.error for s in sreqs]
    assert all(len(s.out_tokens) == max_new for s in sreqs)
    # invariant 3: un-faulted streams are token-identical to fault-free
    poisoned = eng.faults.uids_poisoned
    clean = [i for i, s in enumerate(sreqs)
             if s._req.uid not in poisoned]
    assert clean, "seeded plan poisoned every stream; weaken the plan"
    for i in clean:
        assert sreqs[i].out_tokens == ref[i], \
            f"un-faulted stream {i} diverged from the fault-free run"
    # poisoned streams recovered through the guard, not by luck
    if poisoned:
        c = eng.metrics.snapshot()["counters"]
        assert c["guard.fallbacks"] > 0


def test_seeded_lethal_chaos_terminates_everything(smoke_model):
    """Lethal plans (loop crashes, persistent errors): the only promised
    outcome is containment — every submitted stream terminal, no leaks,
    orchestrator flagged unhealthy if a loop died."""
    cfg, params, prompts = smoke_model
    plan = FaultPlan.random(7, n=8, rounds=20, slots=2, lethal=True)
    eng = _engine(cfg, params, faults=plan, retry=RETRY, guard=True)
    orch = Orchestrator(eng, OrchestratorConfig())
    submitted = []
    for s in [StreamingRequest(p, max_new=10) for p in prompts]:
        try:
            if orch.submit(s, timeout=60.0):
                submitted.append(s)
        except RuntimeError:
            break                            # containment beat us to it
    for s in submitted:
        assert s.wait(120.0), "request never reached a terminal state"
    try:
        orch.close()
    except RuntimeError:
        pass                                 # leaked-thread report is ok
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# per-failure-mode scenarios
# ---------------------------------------------------------------------------

def test_transient_retry_token_identity(smoke_model):
    """Transient stage errors are absorbed by bounded retry and the
    output is bit-identical to the fault-free run."""
    cfg, params, prompts = smoke_model
    ref = _baseline(cfg, params, prompts[:4], 8)
    plan = FaultPlan((
        Fault("stage_error", stage="generate", at=1, count=2),
        Fault("stage_error", stage="prefill", at=1),
        Fault("stage_error", stage="insert", at=2),
    ))
    eng = _engine(cfg, params, faults=plan, retry=RETRY)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=8)
            for i, p in enumerate(prompts[:4])]
    eng.serve(reqs)
    assert [r.out_tokens for r in reqs] == ref
    c = eng.metrics.snapshot()["counters"]
    assert c["stage.retries"] >= 4 and c["faults.injected"] == 4
    _assert_drained(eng)


def test_persistent_stage_error_is_contained(smoke_model):
    """A non-transient stage failure exhausts nothing (retry only covers
    transient faults) and kills the scheduler loop; containment finishes
    every stream with an error and the engine drains clean."""
    cfg, params, prompts = smoke_model
    plan = FaultPlan((Fault("stage_error", stage="generate", at=2,
                            transient=False),))
    eng = _engine(cfg, params, faults=plan, retry=RETRY)
    orch = Orchestrator(eng, OrchestratorConfig())
    sreqs = [StreamingRequest(p, max_new=50) for p in prompts[:4]]
    submitted = [s for s in sreqs if orch.submit(s, timeout=60.0)]
    for s in submitted:
        assert s.wait(120.0)
    assert all(s.error for s in submitted)
    assert not orch.healthy
    assert isinstance(orch.worker_exc, InjectedFault)
    with pytest.raises(RuntimeError, match="unhealthy"):
        orch.submit(StreamingRequest(prompts[0]))
    orch.close()
    _assert_drained(eng)


def test_poison_quarantine_precision_fallback(smoke_model):
    """A NaN-poisoned slot is quarantined and re-decoded up the ladder:
    the stream completes without error, ``guard.fallbacks > 0``, and the
    un-poisoned neighbour stays token-identical to fault-free."""
    cfg, params, prompts = smoke_model
    ref = _baseline(cfg, params, prompts[:2], 10)
    plan = FaultPlan((Fault("poison_logits", at=3, slot=0,
                            fixed_by_level=2),))
    eng = _engine(cfg, params, faults=plan, retry=RETRY, guard=True)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=10)
            for i, p in enumerate(prompts[:2])]
    eng.serve(reqs)
    assert all(r.done and r.error is None for r in reqs)
    c = eng.metrics.snapshot()["counters"]
    assert c["guard.nonfinite_rows"] == 1
    assert c["guard.fallbacks"] == 2         # rung 1 still NaN, rung 2 fixes
    assert c["guard.exhausted"] == 0
    (poisoned_uid,) = eng.faults.uids_poisoned
    assert eng.guard.level(poisoned_uid) == 2
    clean = [r for r in reqs if r.uid != poisoned_uid]
    assert [r.out_tokens for r in clean] \
        == [ref[r.uid] for r in clean]       # zero blast radius
    _assert_drained(eng)


def test_guard_ladder_exhaustion_fails_one_request(smoke_model):
    """Non-finite logits that persist through the whole ladder terminate
    that request with an error; the batch neighbour is untouched."""
    cfg, params, prompts = smoke_model
    plan = FaultPlan((Fault("poison_logits", at=3, slot=0,
                            fixed_by_level=99),))
    eng = _engine(cfg, params, faults=plan, retry=RETRY, guard=True)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=10)
            for i, p in enumerate(prompts[:2])]
    eng.serve(reqs)
    (poisoned_uid,) = eng.faults.uids_poisoned
    bad = next(r for r in reqs if r.uid == poisoned_uid)
    good = next(r for r in reqs if r.uid != poisoned_uid)
    assert bad.done and "precision-fallback ladder" in bad.error
    assert good.done and good.error is None
    assert len(good.out_tokens) == 10
    assert eng.metrics.snapshot()["counters"]["guard.exhausted"] == 1
    _assert_drained(eng)


def test_pool_dry_fault_evicts_and_recovers(smoke_model):
    """An injected dry pool mid-growth evicts the newest sequence;
    recompute-on-readmit keeps every stream identical to fault-free."""
    cfg, params, prompts = smoke_model
    ref = _baseline(cfg, params, prompts[:4], 10)
    # alloc calls 0/1 are the two admissions (max_batch=2; queued
    # requests don't reach alloc while slots are full), so call 2 is the
    # first mid-decode growth alloc — the eviction path
    plan = FaultPlan((Fault("pool_dry", at=2, count=2),))
    eng = _engine(cfg, params, faults=plan, retry=RETRY)
    reqs = [Request(uid=i, prompt=np.asarray(p, np.int32), max_new=10)
            for i, p in enumerate(prompts[:4])]
    stats = eng.serve(reqs)
    assert stats["evictions"] >= 1
    assert [r.out_tokens for r in reqs] == ref
    _assert_drained(eng)


def test_deadline_expiry_reclaims_slot(smoke_model):
    cfg, params, prompts = smoke_model
    eng = _engine(cfg, params)
    orch = Orchestrator(eng, OrchestratorConfig(deadline_s=0.05))
    doomed = StreamingRequest(prompts[0], max_new=100_000)
    assert orch.submit(doomed)
    assert doomed.wait(60.0)
    assert doomed.error == "deadline"
    # the freed slot serves later requests normally (no deadline)
    ok = StreamingRequest(prompts[1], max_new=6, deadline_s=120.0)
    assert orch.submit(ok)
    assert ok.wait(60.0) and ok.error is None and len(ok.out_tokens) == 6
    assert orch.stats["deadline_expired"] == 1
    orch.close()
    _assert_drained(eng)


def test_cancel_mid_decode(smoke_model):
    cfg, params, prompts = smoke_model
    eng = _engine(cfg, params)
    orch = Orchestrator(eng, OrchestratorConfig())
    s = StreamingRequest(prompts[0], max_new=100_000)
    assert orch.submit(s)
    while not s.out_tokens:                   # genuinely mid-decode
        time.sleep(0.005)
    s.cancel()
    assert s.wait(60.0)
    assert s.error == "cancelled" and s.cancelled
    assert 0 < len(s.out_tokens) < 100_000
    lc = s.lifecycle()
    assert "submit" in lc and "finish" in lc and "first_token" in lc
    assert orch.stats["cancelled"] == 1
    orch.close()
    _assert_drained(eng)


def test_detok_crash_containment(smoke_model):
    cfg, params, prompts = smoke_model
    plan = FaultPlan((Fault("detok_crash", at=1),))
    eng = _engine(cfg, params, faults=plan)
    orch = Orchestrator(eng, OrchestratorConfig())
    sreqs = [StreamingRequest(p, max_new=30) for p in prompts[:4]]
    submitted = [s for s in sreqs if orch.submit(s, timeout=60.0)]
    for s in submitted:
        assert s.wait(120.0), "stream stranded behind a dead detokenizer"
    assert not orch.healthy
    h = orch.health()
    assert h["worker_exc"] and "detok" in h["error"]
    orch.close()
    _assert_drained(eng)


def test_tokenize_crash_containment(smoke_model):
    cfg, params, prompts = smoke_model
    plan = FaultPlan((Fault("tokenize_crash", at=1),))
    eng = _engine(cfg, params, faults=plan)
    orch = Orchestrator(eng, OrchestratorConfig())
    sreqs = [StreamingRequest(p, max_new=8) for p in prompts[:4]]
    submitted = [s for s in sreqs if orch.submit(s, timeout=60.0)]
    for s in submitted:
        assert s.wait(120.0), "stream stranded after a tokenize crash"
    # the crash victim itself carries the tokenize error, the rest the
    # containment error — nobody hangs
    assert any("tokenize failed" in (s.error or "") for s in submitted)
    assert not orch.healthy
    orch.close()
    _assert_drained(eng)


def test_sched_crash_health_and_exit_propagation(smoke_model):
    cfg, params, prompts = smoke_model
    plan = FaultPlan((Fault("sched_crash", at=3),))
    eng = _engine(cfg, params, faults=plan)
    with pytest.raises(RuntimeError, match="worker crashed") as ei:
        with Orchestrator(eng, OrchestratorConfig()) as orch:
            sreqs = [StreamingRequest(p, max_new=50) for p in prompts[:4]]
            submitted = []
            for s in sreqs:
                try:
                    if orch.submit(s, timeout=60.0):
                        submitted.append(s)
                except RuntimeError:
                    break
            for s in submitted:
                assert s.wait(120.0)
            orch._sched.join(30.0)          # let the dying loop finish
            h = orch.health()
            assert not h["healthy"] and h["in_flight"] == 0
            assert h["threads"]["orch-scheduler"] is False
            assert set(h["threads"]) == {"orch-scheduler", "orch-detok"}
            assert h["engine"]["live_pages"] == 0
    assert isinstance(ei.value.__cause__, InjectedFault)
    _assert_drained(eng)


def test_watchdog_fails_stuck_scheduler(smoke_model):
    """A 2s injected straggler against a 0.2s watchdog: in-flight
    requests fail fast instead of hanging for the stage duration."""
    cfg, params, prompts = smoke_model
    plan = FaultPlan((Fault("stage_delay", stage="generate", at=2,
                            delay_s=2.0),))
    eng = _engine(cfg, params, faults=plan)
    orch = Orchestrator(eng, OrchestratorConfig(watchdog_s=0.2))
    s = StreamingRequest(prompts[0], max_new=300)
    assert orch.submit(s)
    t0 = time.perf_counter()
    assert s.wait(60.0)
    assert time.perf_counter() - t0 < 1.9    # failed before the stall ended
    assert "watchdog" in s.error
    assert not orch.healthy
    assert orch.stats["watchdog_fired"] == 1
    orch.close()                             # straggler finishes inside 60s
    _assert_drained(eng)


def test_close_raises_on_leaked_threads(smoke_model):
    cfg, params, prompts = smoke_model
    plan = FaultPlan((Fault("stage_delay", stage="generate", at=2,
                            delay_s=3.0),))
    eng = _engine(cfg, params, faults=plan)
    orch = Orchestrator(eng, OrchestratorConfig())
    s = StreamingRequest(prompts[0], max_new=300)
    assert orch.submit(s)
    while not s.out_tokens:
        time.sleep(0.005)
    with pytest.raises(RuntimeError, match="leaked threads"):
        orch.close(timeout=0.2)
    # drain the straggler so it cannot bleed into other tests
    orch._sched.join(30.0)
    orch._detok.join(30.0)
    assert not orch._sched.is_alive() and not orch._detok.is_alive()


# ---------------------------------------------------------------------------
# units: plan parsing, allocator checks, ladder derivation
# ---------------------------------------------------------------------------

def test_fault_plan_parse_and_determinism(tmp_path):
    assert FaultPlan.parse("none").faults == ()
    p1 = FaultPlan.parse("random:seed=3,n=5,rounds=10,slots=2")
    p2 = FaultPlan.parse("random:seed=3,n=5,rounds=10,slots=2")
    assert p1 == p2 and len(p1.faults) == 5 and p1.seed == 3
    assert p1 != FaultPlan.parse("random:seed=4,n=5,rounds=10,slots=2")
    lethal = FaultPlan.random(0, n=40, lethal=True)
    kinds = {f.kind for f in lethal.faults}
    assert kinds & {"sched_crash", "detok_crash", "tokenize_crash"}
    benign = FaultPlan.random(0, n=40)
    assert all(f.transient for f in benign.faults
               if f.kind == "stage_error")
    path = tmp_path / "plan.json"
    path.write_text(json.dumps([
        {"kind": "stage_error", "stage": "generate", "at": 1},
        {"kind": "poison_logits", "slot": 1, "fixed_by_level": 2},
    ]))
    plan = FaultPlan.parse(str(path))
    assert plan.faults[0].stage == "generate"
    assert plan.faults[1].fixed_by_level == 2
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor_strike")
    with pytest.raises(ValueError, match="stage site"):
        Fault("stage_error")


def test_injected_fork_failure_leaves_allocator_consistent():
    alloc = PageAllocator(8, 4, faults=FaultInjector(
        FaultPlan((Fault("fork_fail", at=1),))))
    pages = alloc.alloc(3)
    forked = alloc.fork(pages)               # call 0: fine
    with pytest.raises(InjectedFault):
        alloc.fork(pages)                    # call 1: injected failure
    # the failed fork mutated nothing: refcounts still cover exactly the
    # two owners, and a full free drains the pool
    alloc.assert_consistent()
    assert all(alloc.ref_count(p) == 2 for p in pages)
    alloc.free(forked)
    alloc.free(pages)
    assert alloc.live_pages == 0
    alloc.assert_consistent()


def test_assert_consistent_catches_corruption():
    alloc = PageAllocator(6, 4)
    pages = alloc.alloc(2)
    alloc.assert_consistent()                # healthy state passes
    alloc._refs[pages[0]] = 0                # simulate a lost reference
    with pytest.raises(AssertionError, match="mismatch"):
        alloc.assert_consistent()
    alloc._refs[pages[0]] = 1
    alloc._free.append(alloc._free[-1])      # simulate a double free
    with pytest.raises(AssertionError, match="duplicates"):
        alloc.assert_consistent()


def test_fallback_ladder_shapes():
    ladder = fallback_ladder(PRESETS["paper_edge_p8"])
    assert len(ladder) == 2                  # posit16 rung, then full
    assert ladder[0].attn_weights == "posit16_2"
    assert ladder[1].attn_weights is None
    # KV settings never move — every rung reads the same decode state
    for rung in ladder:
        assert rung.kv_format == PRESETS["paper_edge_p8"].kv_format
        assert rung.kv_layout == PRESETS["paper_edge_p8"].kv_layout
    (retry_rung,) = fallback_ladder(BF16)    # full precision: one retry
    assert retry_rung.attn_weights is None
    assert "guard_retry" in retry_rung.name
