"""Paged posit KV cache: block allocator semantics, paged Pallas kernels
vs pure-jnp oracles, ring/paged greedy equivalence, and the continuous-
batching engine with true per-slot positions (mixed prompt lengths, slot
reuse after EOS, head-of-line admission)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.formats import POSIT4_1, POSIT8_2, POSIT16_2
from repro.core.transprecision import BF16
from repro.kernels import kv_cache as kvk
from repro.kernels import paged_kv as pkv
from repro.models import lm
from repro.models.serve_model import decode_step, init_cache, prefill
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.serve.paged import PageAllocator, SlotPages, pages_for

FMTS = [("posit16", POSIT16_2, False), ("posit8", POSIT8_2, False),
        ("posit4", POSIT4_1, True)]


# ---------------------------------------------------------------------------
# Block allocator
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_reuse():
    a = PageAllocator(num_pages=5, page_size=4)
    assert a.num_free == 4 and a.live_pages == 0      # page 0 reserved
    p1 = a.alloc(2)
    p2 = a.alloc(2)
    assert a.alloc(1) is None                          # exhausted
    assert sorted(p1 + p2) == [1, 2, 3, 4]
    assert 0 not in p1 + p2                            # trash never handed out
    a.free(p1)
    assert a.num_free == 2 and a.live_pages == 2
    p3 = a.alloc(2)                                    # freed pages come back
    assert sorted(p3) == sorted(p1)
    with pytest.raises(ValueError):
        a.free(p1 + p1)                                # double free detected


def test_allocator_fork_refcounts():
    a = PageAllocator(num_pages=4, page_size=2)
    p = a.alloc(2)
    shared = a.fork(p)
    assert shared == p and a.ref_count(p[0]) == 2
    a.free(p)                                          # first owner drops
    assert a.num_free == 1                             # still shared
    a.free(shared)
    assert a.num_free == 3                             # now returned


def test_slot_pages_growth_and_table_row():
    sp = SlotPages(page_size=4, pages=[3, 1])
    assert sp.pages_needed(8) == 0
    assert sp.pages_needed(9) == 1
    row = sp.table_row(5)
    assert row.tolist() == [3, 1, 0, 0, 0]
    assert pages_for(0, 4) == 0 and pages_for(1, 4) == 1 and pages_for(9, 4) == 3


def test_flat_dst_rows_clamps_idle_slots():
    table = jnp.asarray([[2, 3], [0, 0]], jnp.int32)
    rows = pkv.flat_dst_rows(table, jnp.asarray([5, 99]), page_size=4)
    # slot 0: page 3 (logical 1), offset 1; slot 1: clamped to trash page
    assert rows.tolist() == [3 * 4 + 1, 0 * 4 + 3]


# ---------------------------------------------------------------------------
# Paged Pallas kernels vs pure-jnp oracles (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,fmt,packed", FMTS, ids=lambda x: str(x))
def test_paged_append_kernel_bit_exact(name, fmt, packed):
    rng = np.random.default_rng(2)
    b, nkv, hd, ps, npages = 3, 2, 16, 4, 7
    dc = kvk.code_channels(hd, fmt, packed)
    kc = jnp.zeros((npages * ps, nkv, dc), fmt.storage_dtype)
    ks = jnp.ones((npages * ps, nkv), jnp.float32)
    vc, vs = kc, ks
    table = jnp.asarray([[1, 2, 0], [3, 4, 0], [5, 6, 0]], jnp.int32)
    for pos in ([0, 1, 2], [3, 4, 7], [5, 6, 4]):     # incl. 2nd-page writes
        kn = jnp.asarray(rng.normal(0, .5, (b, 1, nkv, hd)), jnp.float32)
        vn = jnp.asarray(rng.normal(0, 2., (b, 1, nkv, hd)), jnp.float32)
        dst = pkv.flat_dst_rows(table, jnp.asarray(pos), ps)
        got = pkv.paged_kv_append(kc, ks, vc, vs, kn, vn, dst, fmt,
                                  packed=packed, interpret=True)
        want = pkv.paged_kv_append_ref(kc, ks, vc, vs, kn, vn, dst, fmt,
                                       packed)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        kc, ks, vc, vs = got


@pytest.mark.parametrize("name,fmt,packed", FMTS, ids=lambda x: str(x))
@pytest.mark.parametrize("lens", [(1, 1, 1), (6, 12, 11), (3, 8, 12)])
def test_paged_decode_attention_matches_ref(name, fmt, packed, lens):
    rng = np.random.default_rng(3)
    b, nkv, grp, hd, ps, npages = 3, 2, 2, 8, 4, 7
    R = npages * ps
    kf = rng.normal(0, 1, (R, nkv, hd)).astype(np.float32)
    vf = rng.normal(0, 1, (R, nkv, hd)).astype(np.float32)
    kc, ks = kvk.encode_kv_rows(jnp.asarray(kf), fmt, packed)
    vc, vs = kvk.encode_kv_rows(jnp.asarray(vf), fmt, packed)
    ks, vs = ks[..., 0], vs[..., 0]
    table = jnp.asarray([[1, 2, 0], [3, 4, 5], [6, 1, 2]], jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (b, 1, nkv * grp, hd)), jnp.float32)
    seq_lens = jnp.asarray(lens, jnp.int32)
    got = pkv.paged_decode_attention(q, kc, ks, vc, vs, table, seq_lens,
                                     fmt, page_size=ps, packed=packed,
                                     interpret=True)
    want = pkv.paged_decode_attention_ref(q, kc, ks, vc, vs, table,
                                          seq_lens, fmt, page_size=ps,
                                          packed=packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gather_pages_logical_order():
    pool = jnp.arange(4 * 2 * 3, dtype=jnp.float32).reshape(4 * 2, 3)
    table = jnp.asarray([[2, 1], [3, 0]], jnp.int32)
    out = pkv.gather_pages(pool, table, page_size=2)
    np.testing.assert_array_equal(np.asarray(out[0, :2]), np.asarray(pool[4:6]))
    np.testing.assert_array_equal(np.asarray(out[0, 2:]), np.asarray(pool[2:4]))
    np.testing.assert_array_equal(np.asarray(out[1, :2]), np.asarray(pool[6:8]))


# ---------------------------------------------------------------------------
# Ring/paged equivalence (standalone model level) + engine batching
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n) for n in (4, 11, 7)]
    return cfg, params, prompts


def _greedy_single(cfg, params, prompt, policy, max_len, max_new):
    """Single-sequence greedy decode: the per-request ground truth."""
    tokens = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = prefill(params, {"tokens": tokens}, cfg, max_len, policy)
    out = [int(np.argmax(np.asarray(logits)[0][: cfg.vocab]))]
    for _ in range(max_new - 1):
        logits, cache = decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32), cfg, policy)
        out.append(int(np.argmax(np.asarray(logits)[0][: cfg.vocab])))
    return out


@pytest.mark.parametrize("kvf", ["posit16", "posit8"])
def test_paged_matches_ring_standalone(smoke_model, kvf):
    """Acceptance: paged greedy decode == ring greedy decode, token for
    token, for the posit formats (jnp-reference backend)."""
    cfg, params, prompts = smoke_model
    ring = dataclasses.replace(BF16, kv_format=kvf, name=f"tr_{kvf}")
    paged = dataclasses.replace(BF16, kv_format=kvf, kv_layout="paged",
                                kv_page_size=4, name=f"tp_{kvf}")
    t_ring = _greedy_single(cfg, params, prompts[1], ring, 32, 6)
    t_paged = _greedy_single(cfg, params, prompts[1], paged, 32, 6)
    assert t_ring == t_paged


@pytest.mark.parametrize("layout", ["ring", "paged"])
@pytest.mark.parametrize("kvf", ["f32", "posit16"])
def test_engine_mixed_lengths_match_single_sequence(smoke_model, kvf, layout):
    """Continuous batching with heterogeneous prompt lengths and slot
    reuse: every request's greedy stream must equal its single-sequence
    decode (true per-slot positions; the old shared-pos engine could
    not pass this)."""
    cfg, params, prompts = smoke_model
    policy = dataclasses.replace(BF16, kv_format=kvf, name=f"te_{kvf}")
    refs = [_greedy_single(cfg, params, p, policy, 32, 5) for p in prompts]
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=32, kv_format=kvf,
                                    kv_layout=layout, page_size=4))
    reqs = [Request(uid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    eng.serve(reqs)
    assert [r.out_tokens for r in reqs] == refs


def test_engine_posit8_paged_runs(smoke_model):
    cfg, params, prompts = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=32,
                                    kv_format="posit8", kv_layout="paged",
                                    page_size=4))
    reqs = [Request(uid=0, prompt=prompts[0], max_new=4)]
    stats = eng.serve(reqs)
    assert len(reqs[0].out_tokens) == 4 and stats["tokens"] > 0


def test_engine_slot_reuse_after_eos_frees_pages(smoke_model):
    """EOS mid-stream frees the slot AND its pages; later queue entries
    reuse both; at drain the pool is fully free again."""
    cfg, params, prompts = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=32, kv_format="f32",
                                    kv_layout="paged", page_size=4,
                                    eos_id=0))
    reqs = [Request(uid=i, prompt=prompts[i % len(prompts)], max_new=8)
            for i in range(5)]
    stats = eng.serve(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) >= 1 for r in reqs)
    assert stats["prefills"] == 5
    assert eng.allocator.live_pages == 0               # no page leaks
    eng.allocator.assert_consistent()
    assert eng.kv_cache_live_bytes() == 0
    assert stats["peak_live_pages"] > 0


def test_engine_no_head_of_line_blocking(smoke_model):
    """An unplaceable queue head must not starve later entries: an
    oversized prompt is rejected outright, and a page-infeasible one
    (paged) is rejected instead of spinning forever."""
    cfg, params, prompts = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=16, kv_format="f32",
                                    kv_layout="paged", page_size=4,
                                    num_pages=5))
    rng = np.random.default_rng(1)
    too_long = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 20),
                       max_new=4)
    # feasible prompts; 12 tokens needs 4 pages = every allocatable page
    big = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 11), max_new=3)
    small = Request(uid=2, prompt=rng.integers(0, cfg.vocab, 3), max_new=3)
    stats = eng.serve([too_long, big, small])
    assert too_long.done and too_long.error is not None
    assert not too_long.out_tokens
    assert stats["rejected"] == 1
    assert len(big.out_tokens) == 3 and len(small.out_tokens) == 3


def test_engine_transient_page_pressure_admits_later_entries(smoke_model):
    """With the pool too tight for the queue head, later small requests
    are admitted first and the head lands once pages free up."""
    cfg, params, prompts = smoke_model
    rng = np.random.default_rng(2)
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=16, kv_format="f32",
                                    kv_layout="paged", page_size=4,
                                    num_pages=6))
    small = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 3), max_new=3)
    # reserves 4 of the 5 allocatable pages: can't start beside a small
    big = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 11), max_new=3)
    small2 = Request(uid=2, prompt=rng.integers(0, cfg.vocab, 3), max_new=3)
    stats = eng.serve([small, big, small2])
    assert stats["rejected"] == 0
    for r in (small, big, small2):
        assert r.done and len(r.out_tokens) == 3


def test_engine_max_new_zero_reserves_first_append_page(smoke_model):
    """Regression: a page-aligned prompt with max_new=0 must still reserve
    the page its first (and only) decode append lands in — otherwise the
    admission invariant undercounts and the request can starve."""
    cfg, params, _ = smoke_model
    rng = np.random.default_rng(3)
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=1, max_len=16, kv_format="f32",
                                    kv_layout="paged", page_size=4,
                                    num_pages=3))
    req = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4), max_new=0)
    assert eng._worst_pages(req) == 2          # prompt page + append page
    eng.serve([req], max_ticks=50)
    assert req.done and len(req.out_tokens) == 1
    assert eng.allocator.live_pages == 0
    eng.allocator.assert_consistent()


@pytest.mark.parametrize("kvf", ["bf16", "posit8"])
@pytest.mark.parametrize("layout", ["ring", "paged"])
def test_kv_cache_bytes_reports_all_layouts(smoke_model, kvf, layout):
    """Satellite: kv_cache_bytes must be non-zero for every layout (the
    old implementation returned 0 for non-ring key layouts), and the
    paged live accounting stays <= reserved."""
    cfg, params, _ = smoke_model
    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=2, max_len=32, kv_format=kvf,
                                    kv_layout=layout, page_size=4))
    reserved = eng.kv_cache_bytes()
    assert reserved > 0
    assert eng.kv_cache_live_bytes() <= reserved
    if layout == "paged":
        assert eng.kv_cache_live_bytes() == 0          # nothing admitted yet
