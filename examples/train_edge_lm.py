"""End-to-end driver: train the ~100M paper-edge LM with transprecision.

Full-scale invocation (the deliverable-(b) run; ~100M params, a few
hundred steps — sized for a real accelerator, runnable on CPU if you have
the patience):

  PYTHONPATH=src python examples/train_edge_lm.py --full --steps 300

Default invocation is a CPU-sized smoke (reduced width, 60 steps) that
exercises the identical code path: deterministic pipeline -> TC train step
(P(8,2) weights via STE) -> AdamW -> atomic async checkpoints -> restart.
"""
import argparse

from repro.configs import get_config
from repro.core.transprecision import PRESETS
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="the real ~100M config (12L/768d/32k vocab)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", default="paper_edge_p8",
                    choices=sorted(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_edge_ckpt")
    args = ap.parse_args()

    cfg = get_config("paper-edge", smoke=not args.full)
    print(f"arch={cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"policy={args.policy}")
    tcfg = TrainerConfig(
        steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        checkpoint_dir=args.ckpt_dir, checkpoint_every=max(args.steps // 4, 1),
        log_every=max(args.steps // 12, 1))
    opt = AdamWConfig(lr=1e-3, total_steps=args.steps,
                      warmup_steps=max(args.steps // 10, 1))
    trainer = Trainer(cfg, tcfg, opt, policy=args.policy)
    out = trainer.run()
    h = out["history"]
    print(f"\nloss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{args.steps} steps "
          f"(ckpts at {args.ckpt_dir}: {trainer.ckpt.steps()})")
    assert h[-1]["loss"] < h[0]["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
