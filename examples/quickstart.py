"""Quickstart: the paper's contribution in 60 lines.

1. Decode/encode posits with the TALU thermometer algorithm (Algorithm 1).
2. Wrap a weight matrix in a posit QuantizedTensor and matmul through the
   Pallas decode-in-VMEM kernel.
3. Run one transprecision training step where the TC policy puts every
   weight in P(8,2) — the paper's edge configuration.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posit
from repro.core.formats import POSIT8_2
from repro.core.quant import quantize
from repro.core.transprecision import PAPER_EDGE
from repro.configs import get_config
from repro.kernels.ops import qt_matmul
from repro.optim import AdamWConfig
from repro.data.pipeline import make_pipeline
from repro.train.step import init_train_state, make_train_step

# --- 1. posit codec (Algorithm 1: parallel compares -> popcount -> shift)
x = jnp.asarray([0.00024, 1.0, -2.5, 13.0])
codes = posit.encode_f32(x, POSIT8_2)
back = posit.decode_to_f32(codes, POSIT8_2)
print("posit P(8,2) round-trip:")
for xi, ci, bi in zip(x, codes, back):
    print(f"  {float(xi):+9.5f} -> 0b{int(ci):08b} -> {float(bi):+9.5f}")

# --- 2. posit-packed weights through the Pallas matmul kernel
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
w = jnp.asarray(rng.standard_normal((128, 32)) * 0.05, jnp.float32)
wq = quantize(w, POSIT8_2, axis=0)          # per-output-channel pow2 scale
out = qt_matmul(a, wq)                       # decode-in-VMEM + MXU dot
err = jnp.abs(out - a @ w).mean() / jnp.abs(a @ w).mean()
print(f"\nposit8 matmul kernel: mean rel err vs f32 weights = {err:.3f} "
      f"(storage {wq.nbytes_packed} B vs {w.nbytes} B)")

# --- 3. one transprecision training step (paper's P(8,2) edge policy)
cfg = get_config("paper-edge", smoke=True)
opt_cfg = AdamWConfig(total_steps=10)
state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg, PAPER_EDGE)
step = jax.jit(make_train_step(cfg, opt_cfg, PAPER_EDGE), donate_argnums=0)
batch = make_pipeline(cfg, global_batch=4, seq_len=64)(0)
state, metrics = step(state, batch)
print(f"\nTC train step under policy '{PAPER_EDGE.name}': "
      f"loss={float(metrics['loss']):.3f} "
      f"gnorm={float(metrics['grad_norm']):.3f}")
