"""Render the multi-pod dry-run roofline table from the result JSONs.

  PYTHONPATH=src python examples/roofline_report.py

(Equivalent to `python -m benchmarks.roofline`; kept as an example of
consuming the dry-run artifacts programmatically.)
"""
from benchmarks.roofline import main

if __name__ == "__main__":
    main()
