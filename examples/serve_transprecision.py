"""Serve a small LM with batched requests under runtime-switchable
transprecision — the paper's deployment scenario (§IV-D): "if an
application requires FP/INT vector computation, then the design can be
switched ... without any performance overhead".

Serves the same request set under three TC policies (posit8 / int8 /
bf16), switching policy BETWEEN batches at runtime — each policy is just a
different jit specialization, the software analogue of the posit_en /
bitwidth control lines.  Then: KV-cache transprecision (PR 1), the paged
KV layout (PR 2), and self-speculative decoding (PR 3: posit8 draft +
target-precision verify + KV rollback, switching precision WITHIN a
decoding round).

  PYTHONPATH=src python examples/serve_transprecision.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.transprecision import get_policy
from repro.models import lm
from repro.serve.engine import Request, ServeConfig, ServingEngine


def main():
    cfg = get_config("paper-edge", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
               for _ in range(6)]

    outputs = {}
    for policy in ("paper_edge_p8", "int8_w", "bf16"):
        engine = ServingEngine(cfg, params,
                               ServeConfig(max_batch=3, max_len=96),
                               policy=get_policy(policy))
        reqs = [Request(uid=i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        stats = engine.serve(reqs)
        outputs[policy] = [r.out_tokens for r in reqs]
        print(f"policy={policy:14s} tokens/s={stats['tok_per_s']:8.1f} "
              f"decode_steps={stats['decode_steps']}")

    # posit8 weights change logits but the engine stays functional and the
    # higher-precision policies agree with each other more than with posit8
    agree_bf16_int8 = np.mean([a == b for a, b in
                               zip(outputs["bf16"], outputs["int8_w"])])
    print(f"\ngreedy-output agreement bf16 vs int8: {agree_bf16_int8:.2f}")
    print("runtime policy switching: OK (three jit specializations, "
          "no recompilation of unrelated variants)")

    # --- posit-packed KV cache (decode-on-read, PR 1) ------------------
    # Same bf16 weights, but the KV ring holds posit codes + per-row pow2
    # scales; posit16 reproduces the f32-cache greedy outputs at ~half the
    # cache footprint, posit8 at a quarter.
    print("\nKV-cache transprecision (bf16 weights, packed K/V ring):")
    kv_out = {}
    for kvf in ("f32", "posit16", "posit8"):
        engine = ServingEngine(cfg, params,
                               ServeConfig(max_batch=3, max_len=96,
                                           kv_format=kvf),
                               policy=get_policy("bf16"))
        reqs = [Request(uid=i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        stats = engine.serve(reqs)
        kv_out[kvf] = [r.out_tokens for r in reqs]
        print(f"  kv_format={kvf:8s} cache={stats['kv_cache_bytes']:7d} B "
              f"tokens/s={stats['tok_per_s']:8.1f}")
    match16 = np.mean([a == b for a, b in
                       zip(kv_out["posit16"], kv_out["f32"])])
    print(f"  greedy agreement posit16-KV vs f32-KV: {match16:.2f}")

    # --- paged KV cache (PR 2): page pool + per-sequence tables --------
    # Same posit codes, but slots stop reserving max_len rings: pages are
    # allocated as sequences grow and returned the moment they finish, so
    # HBM tracks live tokens.  Greedy outputs are bit-identical to the
    # ring layout (true per-slot positions in both).
    print("\nPaged KV cache (posit8 codes, page_size=8):")
    for layout in ("ring", "paged"):
        engine = ServingEngine(cfg, params,
                               ServeConfig(max_batch=3, max_len=96,
                                           kv_format="posit8",
                                           kv_layout=layout, page_size=8),
                               policy=get_policy("bf16"))
        reqs = [Request(uid=i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        stats = engine.serve(reqs)
        kv_out[layout] = [r.out_tokens for r in reqs]
        print(f"  layout={layout:6s} reserved={stats['kv_cache_bytes']:7d} B"
              f" peak_live={stats['kv_peak_live_bytes']:7d} B "
              f"tokens/s={stats['tok_per_s']:8.1f}")
    match = np.mean([a == b for a, b in zip(kv_out["paged"],
                                            kv_out["ring"])])
    print(f"  greedy agreement paged vs ring: {match:.2f} "
          "(exact by construction)")

    # --- self-speculative decoding (PR 3) ------------------------------
    # The TALU story end to end: gamma draft tokens per round under a
    # derived posit8 policy (posit8 weight compute + posit8 KV ring),
    # then ONE full-precision verify pass scores all gamma+1 positions;
    # accepted tokens commit, the first rejection rolls the KV cache
    # back (ring rewind / paged page-free).  Greedy output is
    # token-identical to the baseline engine — the draft precision only
    # sets the ACCEPTANCE RATE, i.e. how many target-model steps each
    # token costs.
    from repro.serve.speculative import SpeculativeEngine
    print("\nSelf-speculative decode (draft=posit8 weights+KV, "
          "target=f32 KV):")
    base = ServingEngine(cfg, params,
                         ServeConfig(max_batch=3, max_len=96,
                                     kv_format="f32"),
                         policy=get_policy("bf16"))
    reqs = [Request(uid=i, prompt=p, max_new=12)
            for i, p in enumerate(prompts)]
    base.serve(reqs)
    base_out = [r.out_tokens for r in reqs]
    for gamma in (2, 4):
        engine = SpeculativeEngine(cfg, params,
                                   ServeConfig(max_batch=3, max_len=96,
                                               kv_format="f32"),
                                   policy=get_policy("bf16"), gamma=gamma)
        reqs = [Request(uid=i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        stats = engine.serve(reqs)
        acc = stats["drafts_accepted"] / max(stats["drafts_proposed"], 1)
        spt = stats["decode_steps"] / max(stats["tokens"]
                                          - stats["prefills"], 1)
        ident = [r.out_tokens for r in reqs] == base_out
        print(f"  gamma={gamma}: acceptance={acc:.2f} "
              f"target steps/token={spt:.2f} "
              f"identical to baseline greedy: {ident}")
    print("  (< 1.0 target steps/token = the expensive datapath runs "
          "less than once per token)")

    # --- disaggregated engine API + async orchestrator (PR 4) ----------
    # Serving is now three separately jitted stages over one decode
    # state:  prefill(params, tokens, lengths) -> Prefix  (bucketed-
    # length prompt batch),  insert(prefix, state, slot)  (merge into a
    # free slot — paged prefixes scatter straight into pool pages), and
    # generate(params, state)  (one tick for the whole batch).  The
    # Orchestrator drives those stages from background threads with a
    # backpressured queue and per-token streaming callbacks.
    from repro.serve.orchestrator import (Orchestrator, OrchestratorConfig,
                                          StreamingRequest)
    print("\nAsync orchestrator (three-stage engine, streaming):")
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=3, max_len=96,
                                       kv_format="posit8"),
                           policy=get_policy("bf16"))
    pieces = []
    with Orchestrator(engine, OrchestratorConfig(max_queue=8)) as orch:
        sreqs = [StreamingRequest(p.tolist(), max_new=12,
                                  on_token=lambda r, ids, s:
                                  pieces.append(len(ids)))
                 for p in prompts]
        for s in sreqs:
            orch.submit(s, timeout=60.0)
        for s in sreqs:
            s.wait(120.0)
    ttfts = [s.ttft_s * 1e3 for s in sreqs]
    print(f"  {orch.stats['finished']} streams, "
          f"{sum(len(s.out_tokens) for s in sreqs)} tokens in "
          f"{len(pieces)} streamed callbacks; "
          f"median TTFT {sorted(ttfts)[len(ttfts) // 2]:.1f} ms")

    # --- observability (PR 5): spans, metrics, stage attribution -------
    # Every engine carries a span tracer and a metrics registry
    # (repro.obs).  With the tracer enabled, each engine stage records a
    # host-dispatch span (Python + jit dispatch) and a device span (the
    # block_until_ready wait), so the wall clock decomposes into
    # per-stage dispatch vs device time — the tool for ROADMAP direction
    # 1's "where does the speculative wall clock go" question.  Disabled
    # (the default), the spans cost ~nothing and the engine never
    # synchronizes.  The same registry backs engine.stats / orch.stats,
    # with latency histograms (p50/p95/p99) per stage for free.
    from time import perf_counter

    from repro.obs import Tracer, format_breakdown, stage_breakdown
    print("\nObservability (span tracer + metrics registry):")
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=3, max_len=96,
                                       kv_format="posit8"),
                           policy=get_policy("bf16"),
                           tracer=Tracer(enabled=True))
    reqs = [Request(uid=i, prompt=p, max_new=12)
            for i, p in enumerate(prompts)]
    t0 = perf_counter()
    engine.serve(reqs)
    wall = perf_counter() - t0
    print(format_breakdown(stage_breakdown(engine.tracer, wall)))
    gen = engine.metrics.histogram("stage.generate.dispatch_s")
    print(f"  generate dispatch p50/p99: {gen.percentile(50) * 1e3:.1f}/"
          f"{gen.percentile(99) * 1e3:.1f} ms over {gen.count} calls")
    # engine.tracer.write_chrome_trace("serve.trace.json") -> load the
    # file in chrome://tracing or https://ui.perfetto.dev; the CLI
    # equivalent is `python -m repro.launch.serve --trace-out ...`

    # --- energy & SLO observability (PR 8) -----------------------------
    # EnergyAccountant prices each jitted stage from its *compiled* HLO:
    # MAC flops (dot/conv only — the posit fake-quant emulation is the
    # modeled ALU's native datapath, never priced as flops) x the
    # paper's Table-IV pJ/MAC at the stage's TCPolicy bit widths, plus
    # packed-weight DRAM traffic at 20 pJ/byte.  Multiplied by the live
    # per-stage call counters this gives joules/token next to tok/s —
    # the measurement half of ROADMAP direction 6.
    from repro.obs import EnergyAccountant, format_energy
    print("\nEnergy accounting (modeled, paper Table-IV pJ/MAC):")
    acct = EnergyAccountant(engine)
    print(format_energy(acct.breakdown()))
    # Per-request lifecycle + SLOs: with an Orchestrator, every request
    # carries six stamps (submit -> admit -> prefill_done -> insert_done
    # -> first_token -> finish), so TTFT decomposes into queue-wait vs
    # prefill vs insert (req.lifecycle_deltas()).  OrchestratorConfig
    # (ttft_slo_s=, itl_slo_s=) maintains orch.slo.* violation counters,
    # and request_log="out.jsonl" appends one JSON line per terminal
    # request.  CLI: python -m repro.launch.serve --energy \
    #   --request-log out.jsonl --ttft-slo 200 --itl-slo 50
    # CI gates the trajectory: scripts/bench_compare.py diffs every
    # bench's joules/token, acceptance rate, and latency percentiles
    # against benchmarks/baselines/.

    # --- robustness: chaos-hardened serving (PR 9) ---------------------
    # Deterministic fault injection (repro.serve.faults): a FaultPlan
    # schedules failures by call-site + call index — transient/persistent
    # stage errors, injected stragglers, dry page pools, NaN-poisoned
    # logits, crashed worker loops.  The hardened lifecycle survives it:
    # bounded exponential-backoff retry absorbs transient stage faults,
    # and the numeric guard (repro.serve.guard) quarantines any slot
    # whose logits come back non-finite and re-decodes JUST that slot up
    # a precision-escalation ladder derived from the serving policy
    # (posit8 -> posit16 -> full precision) — the paper's runtime
    # precision reconfiguration applied as a failure policy.  Neighbour
    # slots keep their logits bit-for-bit.
    from repro.serve import Fault, FaultPlan, RetryPolicy
    print("\nChaos hardening (fault injection + numeric guard):")
    plan = FaultPlan((
        Fault("stage_error", stage="generate", at=2, count=2),  # transient
        Fault("poison_logits", at=4, slot=0, fixed_by_level=2),  # NaN row
    ))
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_batch=2, max_len=96),
                           policy=get_policy("paper_edge_p8"),
                           faults=plan, retry=RetryPolicy(), guard=True)
    reqs = [Request(uid=i, prompt=p, max_new=10)
            for i, p in enumerate(prompts[:4])]
    engine.serve(reqs)
    c = engine.metrics.snapshot()["counters"]
    print(f"  injected={int(c['faults.injected'])} "
          f"retries={int(c['stage.retries'])} "
          f"quarantined={int(c['guard.quarantined'])} "
          f"fallback_redecodes={int(c['guard.fallbacks'])} "
          f"-> all {sum(r.done and not r.error for r in reqs)}/4 "
          "requests completed")
    # Orchestrator lifecycle hardening: per-request deadlines
    # (StreamingRequest(deadline_s=...) or OrchestratorConfig.deadline_s
    # -> terminal error="deadline", slot + pages reclaimed), cancel()
    # honored mid-decode, a watchdog that fails in-flight requests if
    # the scheduler stalls (watchdog_s), and crash containment: any
    # worker-loop death finishes EVERY queued/in-flight request with an
    # error and flips orch.healthy — orch.health() snapshots liveness,
    # thread states and the fault/guard counters.  close() raises on
    # leaked threads instead of masking a stuck loop.  CLI:
    #   python -m repro.launch.serve --async \
    #     --fault-plan random:seed=3,n=6 --deadline-s 30 --health
    # The invariants (every request terminal, zero page leaks, un-faulted
    # streams token-identical to fault-free) live in tests/test_chaos.py.


if __name__ == "__main__":
    main()
